//! Minimal HTTP/1.1 server and client (S6), std::net only.
//!
//! The offline registry has no tokio/hyper, and the paper's gateway
//! (CppCMS) is itself a thread-pool HTTP server — so this mirrors that
//! architecture: one accept thread, a bounded queue, and N worker threads
//! (§III-B: "multiple processes for accepting connections and 20 worker
//! threads").  Handlers are routed by (method, path-prefix).

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// HTTP/1.1 persistent connection (absent `Connection: close`).
    pub keep_alive: bool,
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub body: Vec<u8>,
    pub content_type: &'static str,
}

impl Response {
    pub fn ok(body: impl Into<Vec<u8>>) -> Response {
        Response { status: 200, body: body.into(), content_type: "text/plain" }
    }
    pub fn json(body: impl Into<Vec<u8>>) -> Response {
        Response { status: 200, body: body.into(), content_type: "application/json" }
    }
    pub fn not_found() -> Response {
        Response { status: 404, body: b"not found".to_vec(), content_type: "text/plain" }
    }
    pub fn bad_request(msg: &str) -> Response {
        Response { status: 400, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }
    /// 429: the caller exceeded what the platform will queue (overload
    /// shedding at the gateway, rate limits on the invoke path).
    pub fn too_many_requests(msg: &str) -> Response {
        Response { status: 429, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }
    pub fn error(msg: &str) -> Response {
        Response { status: 500, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }
    /// 503: the serving backend is down or draining (engine pool shut
    /// down, coordinator not ready).
    pub fn unavailable(msg: &str) -> Response {
        Response { status: 503, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        self.write_conn(w, false)
    }

    pub fn write_conn(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Parse one request from a buffered stream (request line + headers + body).
/// Returns Ok(None) on clean EOF (client closed a persistent connection).
pub fn parse_request_buf(
    reader: &mut BufReader<TcpStream>,
) -> std::io::Result<Option<Request>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None); // clean close between requests
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("/").to_string();
    if method.is_empty() {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "empty request line"));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let lower = h.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:").map(str::trim) {
            content_length = v.parse().map_err(|_| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, "bad content-length")
            })?;
        } else if let Some(v) = lower.strip_prefix("connection:").map(str::trim) {
            keep_alive = v != "close";
        }
    }
    // Bound request bodies to 16 MiB: the gateway must not be a memory DoS.
    if content_length > 16 << 20 {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Some(Request { method, path, body, keep_alive }))
}

/// Parse one request from a raw stream (compat shim for one-shot use).
pub fn parse_request(stream: &mut TcpStream) -> std::io::Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    parse_request_buf(&mut reader)?
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "closed"))
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// Bounded connection queue feeding the worker pool.
struct ConnQueue {
    q: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    capacity: usize,
}

impl ConnQueue {
    /// Enqueue, or hand the stream back on overload so the caller can
    /// shed it with an explicit 429.
    fn push(&self, s: TcpStream) -> Result<(), TcpStream> {
        let mut q = self.q.lock().unwrap();
        if q.len() >= self.capacity {
            return Err(s);
        }
        q.push_back(s);
        self.cv.notify_one();
        Ok(())
    }

    fn pop(&self, stop: &AtomicBool) -> Option<TcpStream> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(s) = q.pop_front() {
                return Some(s);
            }
            if stop.load(Ordering::Acquire) {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(q, Duration::from_millis(50)).unwrap();
            q = guard;
        }
    }
}

/// Gateway request counters.
#[derive(Default)]
pub struct GatewayStats {
    pub accepted: AtomicU64,
    pub served: AtomicU64,
    pub shed: AtomicU64,
    pub parse_errors: AtomicU64,
}

/// The gateway server.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    pub stats: Arc<GatewayStats>,
}

impl Server {
    /// Bind and serve `handler` with `workers` worker threads.  Pass port 0
    /// for an ephemeral port; the bound address is `addr()`.
    pub fn start(bind: &str, workers: usize, handler: Handler) -> std::io::Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(ConnQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            capacity: 1024,
        });
        let stats = Arc::new(GatewayStats::default());
        let mut threads = Vec::new();

        // Accept thread.
        {
            let (stop, queue, stats) = (stop.clone(), queue.clone(), stats.clone());
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((s, _)) => {
                            stats.accepted.fetch_add(1, Ordering::Relaxed);
                            if let Err(mut s) = queue.push(s) {
                                // Overload: shed with an explicit 429 so
                                // clients can back off instead of timing out.
                                // Off-thread: the drain below may block up
                                // to ~200 ms and must not stall accepts.
                                stats.shed.fetch_add(1, Ordering::Relaxed);
                                std::thread::spawn(move || {
                                    // Drain what the client already sent —
                                    // closing with unread bytes RSTs the
                                    // socket and can discard the 429.
                                    let _ = s.set_read_timeout(Some(Duration::from_millis(50)));
                                    let mut sink = [0u8; 4096];
                                    for _ in 0..4 {
                                        match s.read(&mut sink) {
                                            Ok(n) if n == sink.len() => continue,
                                            _ => break,
                                        }
                                    }
                                    let _ = Response::too_many_requests("gateway queue full")
                                        .write_conn(&mut s, false);
                                });
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        // Worker pool.
        for _ in 0..workers.max(1) {
            let (stop, queue, stats, handler) =
                (stop.clone(), queue.clone(), stats.clone(), handler.clone());
            threads.push(std::thread::spawn(move || {
                while let Some(s) = queue.pop(&stop) {
                    let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
                    let _ = s.set_nodelay(true);
                    let mut writer = match s.try_clone() {
                        Ok(w) => w,
                        Err(_) => continue,
                    };
                    let mut reader = BufReader::new(s);
                    // Serve the whole persistent connection on this worker
                    // (paper-faithful: CppCMS workers are per-connection).
                    loop {
                        match parse_request_buf(&mut reader) {
                            Ok(Some(req)) => {
                                let resp = handler(&req);
                                let keep = req.keep_alive && !stop.load(Ordering::Acquire);
                                // Count before the write completes: clients
                                // may observe the response (and /stats)
                                // before this thread runs again.
                                stats.served.fetch_add(1, Ordering::Relaxed);
                                if resp.write_conn(&mut writer, keep).is_err() || !keep {
                                    break;
                                }
                            }
                            Ok(None) => break, // client closed
                            Err(_) => {
                                stats.parse_errors.fetch_add(1, Ordering::Relaxed);
                                let _ = Response::bad_request("malformed request")
                                    .write_conn(&mut writer, false);
                                break;
                            }
                        }
                    }
                }
            }));
        }

        Ok(Server { addr, stop, threads, stats })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Persistent-connection HTTP client (keep-alive), for load generation —
/// the §Perf L3b optimization: amortizes the TCP connect across requests,
/// mirroring the paper's note that "re-using the same TCP/TLS connection
/// is a powerful optimization option".
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    addr: std::net::SocketAddr,
}

impl HttpClient {
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<HttpClient> {
        let s = TcpStream::connect(addr)?;
        s.set_read_timeout(Some(Duration::from_secs(30)))?;
        s.set_nodelay(true)?;
        Ok(HttpClient { reader: BufReader::new(s), addr })
    }

    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let result = self.request_inner(method, path, body);
        if result.is_err() {
            // Transparent reconnect once (server may have timed us out).
            *self = HttpClient::connect(self.addr)?;
            return self.request_inner(method, path, body);
        }
        result
    }

    fn request_inner(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        {
            let s = self.reader.get_mut();
            write!(
                s,
                "{method} {path} HTTP/1.1\r\nHost: coldfaas\r\nContent-Length: {}\r\n\r\n",
                body.len()
            )?;
            s.write_all(body)?;
            s.flush()?;
        }
        read_response(&mut self.reader)
    }
}

fn read_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<(u16, Vec<u8>)> {
    let mut status_line = String::new();
    if reader.read_line(&mut status_line)? == 0 {
        return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server closed"));
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad content-length {:?}", v.trim()),
                )
            })?;
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

/// Blocking one-shot HTTP client (Connection: close) for tests/examples.
pub fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: coldfaas\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    s.write_all(body)?;
    s.flush()?;
    let mut reader = BufReader::new(s);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad content-length {:?}", v.trim()),
                )
            })?;
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        let handler: Handler = Arc::new(|req: &Request| match req.path.as_str() {
            "/noop" => Response::ok(""),
            p if p.starts_with("/echo") => Response::ok(req.body.clone()),
            _ => Response::not_found(),
        });
        Server::start("127.0.0.1:0", 4, handler).unwrap()
    }

    #[test]
    fn serves_noop() {
        let srv = echo_server();
        let (status, body) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
        assert_eq!(status, 200);
        assert!(body.is_empty());
        srv.shutdown();
    }

    #[test]
    fn echoes_post_body() {
        let srv = echo_server();
        let payload = b"1.5, 2.5, 3.5";
        let (status, body) = http_request(srv.addr(), "POST", "/echo", payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);
        srv.shutdown();
    }

    #[test]
    fn unknown_path_404() {
        let srv = echo_server();
        let (status, _) = http_request(srv.addr(), "GET", "/nope", b"").unwrap();
        assert_eq!(status, 404);
        srv.shutdown();
    }

    #[test]
    fn concurrent_requests() {
        let srv = echo_server();
        let addr = srv.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                std::thread::spawn(move || {
                    let body = format!("req-{i}");
                    let (status, got) =
                        http_request(addr, "POST", "/echo", body.as_bytes()).unwrap();
                    assert_eq!(status, 200);
                    assert_eq!(got, body.as_bytes());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.stats.served.load(Ordering::Relaxed), 16);
        srv.shutdown();
    }

    #[test]
    fn malformed_request_is_400_not_crash() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"\r\n\r\n").unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        // Server must keep serving afterwards.
        let (status, _) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
        assert_eq!(status, 200);
        srv.shutdown();
    }

    #[test]
    fn oversized_body_rejected() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write!(s, "POST /echo HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 64 << 20).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("400"), "got: {text}");
        srv.shutdown();
    }

    #[test]
    fn overload_and_unavailable_status_lines() {
        let mut buf = Vec::new();
        Response::too_many_requests("slow down").write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.ends_with("slow down"));

        let mut buf = Vec::new();
        Response::unavailable("draining").write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Content-Length: 8\r\n"));
    }

    #[test]
    fn keep_alive_serves_many_requests_per_connection() {
        let srv = echo_server();
        let mut c = HttpClient::connect(srv.addr()).unwrap();
        for i in 0..20 {
            let body = format!("r{i}");
            let (status, got) = c.request("POST", "/echo", body.as_bytes()).unwrap();
            assert_eq!(status, 200);
            assert_eq!(got, body.as_bytes());
        }
        assert_eq!(srv.stats.served.load(Ordering::Relaxed), 20);
        // 20 requests over ONE accepted connection.
        assert_eq!(srv.stats.accepted.load(Ordering::Relaxed), 1);
        srv.shutdown();
    }

    #[test]
    fn connection_close_honored() {
        let srv = echo_server();
        // http_request sends Connection: close; server must close after 1.
        let (status, _) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
        assert_eq!(status, 200);
        assert_eq!(srv.stats.served.load(Ordering::Relaxed), 1);
        srv.shutdown();
    }

    #[test]
    fn shutdown_joins_threads() {
        let srv = echo_server();
        let addr = srv.addr();
        srv.shutdown();
        assert!(TcpStream::connect_timeout(&addr.into(), Duration::from_millis(200)).is_err()
            || http_request(addr, "GET", "/noop", b"").is_err());
    }
}
