//! Benchmark-grade HTTP/1.1 server and client (S6/S29), std::net only.
//!
//! The offline registry has no tokio/hyper, and the paper's gateway
//! (CppCMS) is itself a thread-pool HTTP server — so this mirrors that
//! architecture on tiny-http idioms (§III-B: "multiple processes for
//! accepting connections and 20 worker threads"):
//!
//! * a **multi-threaded accept pool** — several accept threads share one
//!   non-blocking listener, so a connection burst is never serialized
//!   behind a single accept loop;
//! * **whole-connection workers** over a [`ReusableStream`] — each worker
//!   owns one persistent connection at a time and serves every request on
//!   it (keep-alive by default for HTTP/1.1, `Connection: close` honored);
//! * **stack-buffer head parsing** — the request line and headers are
//!   scanned in place inside one fixed `[u8; MAX_HEAD_BYTES]` on the
//!   worker's stack: the hot path heap-allocates nothing per header, only
//!   the `Request` fields the handler actually keeps (method/path/body).
//!
//! The parser is strict where it matters for accounting: duplicate
//! `Content-Length` headers, non-numeric lengths, bad method tokens,
//! oversized heads, and oversized bodies are all hard 400s — a request
//! that cannot be framed unambiguously is never served.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Request line + headers must fit this fixed stack buffer.
pub const MAX_HEAD_BYTES: usize = 8192;

/// Bound request bodies: the gateway must not be a memory DoS.
pub const MAX_BODY_BYTES: usize = 16 << 20;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// HTTP/1.1 persistent connection (absent `Connection: close`).
    pub keep_alive: bool,
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub body: Vec<u8>,
    pub content_type: &'static str,
}

impl Response {
    pub fn ok(body: impl Into<Vec<u8>>) -> Response {
        Response { status: 200, body: body.into(), content_type: "text/plain" }
    }
    pub fn json(body: impl Into<Vec<u8>>) -> Response {
        Response { status: 200, body: body.into(), content_type: "application/json" }
    }
    pub fn not_found() -> Response {
        Response { status: 404, body: b"not found".to_vec(), content_type: "text/plain" }
    }
    pub fn bad_request(msg: &str) -> Response {
        Response { status: 400, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }
    /// 429: the caller exceeded what the platform will queue (overload
    /// shedding at the gateway, rate limits on the invoke path).
    pub fn too_many_requests(msg: &str) -> Response {
        Response { status: 429, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }
    pub fn error(msg: &str) -> Response {
        Response { status: 500, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }
    /// 503: the serving backend is down or draining (engine pool shut
    /// down, coordinator not ready).
    pub fn unavailable(msg: &str) -> Response {
        Response { status: 503, body: msg.as_bytes().to_vec(), content_type: "text/plain" }
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        self.write_conn(w, false)
    }

    pub fn write_conn(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" }
        )?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

fn bad(msg: &'static str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Find the `\r\n\r\n` head terminator.
fn find_head_end(hay: &[u8]) -> Option<usize> {
    hay.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Fill `head` from the reader until the blank line ending the head.
///
/// Returns `Ok(None)` on clean EOF before any byte (client closed a
/// persistent connection between requests), `Ok(Some(end))` with the
/// length including the terminator otherwise.  Only head bytes are
/// consumed from the reader — the body stays buffered for the caller.
fn fill_head<R: BufRead>(
    r: &mut R,
    head: &mut [u8; MAX_HEAD_BYTES],
) -> std::io::Result<Option<usize>> {
    let mut len = 0usize;
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            if len == 0 {
                return Ok(None); // clean close between requests
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-head",
            ));
        }
        let take = chunk.len().min(MAX_HEAD_BYTES - len);
        head[len..len + take].copy_from_slice(&chunk[..take]);
        // Re-scan only the window a straddling terminator could occupy.
        let scan_from = len.saturating_sub(3);
        let new_len = len + take;
        if let Some(pos) = find_head_end(&head[scan_from..new_len]) {
            let end = scan_from + pos + 4;
            r.consume(end - len);
            return Ok(Some(end));
        }
        r.consume(take);
        len = new_len;
        if len == MAX_HEAD_BYTES {
            return Err(bad("oversized header"));
        }
    }
}

/// What the in-place head scan extracts; borrows the stack buffer.
struct Head<'a> {
    method: &'a str,
    path: &'a str,
    keep_alive: bool,
    content_length: usize,
}

/// Scan the head slice (sans terminator) without allocating: the request
/// line and every header are inspected as `&str` views into the stack
/// buffer.  Strict by design — see the module docs for the hard-400 list.
fn scan_head(head: &[u8]) -> std::io::Result<Head<'_>> {
    let mut lines = head.split(|&b| b == b'\n').map(|l| l.strip_suffix(b"\r").unwrap_or(l));
    let req_line = lines.next().unwrap_or(b"");
    let req_line = std::str::from_utf8(req_line).map_err(|_| bad("non-utf8 request line"))?;
    let mut parts = req_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    if method.is_empty() {
        return Err(bad("empty request line"));
    }
    if !method.bytes().all(|b| b.is_ascii_alphabetic()) {
        return Err(bad("bad method token"));
    }
    let path = parts.next().unwrap_or("/");
    // Keep-alive is the HTTP/1.1 default; 1.0 must opt in.
    let mut keep_alive = parts.next() != Some("HTTP/1.0");

    let mut content_length: Option<usize> = None;
    for line in lines {
        if line.is_empty() {
            continue; // trailing fragment after the final CRLF
        }
        let line = std::str::from_utf8(line).map_err(|_| bad("non-utf8 header"))?;
        let (name, value) = line.split_once(':').ok_or_else(|| bad("malformed header"))?;
        let (name, value) = (name.trim(), value.trim());
        if name.eq_ignore_ascii_case("content-length") {
            // Duplicate Content-Length headers are a request-smuggling
            // classic; an ambiguous frame is never served (hard 400).
            if content_length.is_some() {
                return Err(bad("duplicate content-length"));
            }
            if value.is_empty() || !value.bytes().all(|b| b.is_ascii_digit()) {
                return Err(bad("bad content-length"));
            }
            content_length = Some(value.parse().map_err(|_| bad("bad content-length"))?);
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        }
    }
    Ok(Head { method, path, keep_alive, content_length: content_length.unwrap_or(0) })
}

/// Parse one request from any buffered stream: head in a stack buffer,
/// then exactly `Content-Length` body bytes.  Returns `Ok(None)` on clean
/// EOF (client closed a persistent connection between requests).
pub fn parse_from<R: BufRead>(reader: &mut R) -> std::io::Result<Option<Request>> {
    let mut head_buf = [0u8; MAX_HEAD_BYTES];
    let end = match fill_head(reader, &mut head_buf)? {
        Some(end) => end,
        None => return Ok(None),
    };
    let head = scan_head(&head_buf[..end - 4])?;
    if head.content_length > MAX_BODY_BYTES {
        return Err(bad("body too large"));
    }
    // Only now does the request touch the heap: the fields the handler
    // keeps (method/path/body), nothing per-header.
    let method = head.method.to_uppercase();
    let path = head.path.to_string();
    let keep_alive = head.keep_alive;
    let mut body = vec![0u8; head.content_length];
    reader.read_exact(&mut body)?;
    Ok(Some(Request { method, path, body, keep_alive }))
}

/// Parse one request from a buffered stream (request line + headers + body).
/// Returns Ok(None) on clean EOF (client closed a persistent connection).
pub fn parse_request_buf(
    reader: &mut BufReader<TcpStream>,
) -> std::io::Result<Option<Request>> {
    parse_from(reader)
}

/// Parse one request from a raw stream (compat shim for one-shot use).
pub fn parse_request(stream: &mut TcpStream) -> std::io::Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    parse_request_buf(&mut reader)?
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "closed"))
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A connection a worker can serve many requests over (tiny-http's
/// `ReadWrite` idiom): one bidirectional stream, owned by one worker for
/// its whole keep-alive lifetime.
pub trait ReusableStream: Read + Write + Send {
    /// Discard whatever the client is still sending on a connection we
    /// are about to fail: closing a socket with unread bytes RSTs it,
    /// which can destroy the error response in flight.  Default: no-op
    /// (in-memory streams have no RST semantics).
    fn discard_pending(&mut self) {}
}

impl ReusableStream for TcpStream {
    fn discard_pending(&mut self) {
        let _ = self.set_read_timeout(Some(Duration::from_millis(50)));
        let mut sink = [0u8; 4096];
        // Bounded drain: enough for any in-flight head/body fragment
        // without letting a firehose client pin the worker.
        for _ in 0..16 {
            match self.read(&mut sink) {
                Ok(n) if n == sink.len() => continue,
                _ => break,
            }
        }
    }
}

/// Serve one whole persistent connection: parse → handle → respond until
/// the client closes, stops keeping alive, or a framing error ends it.
pub fn serve_stream<S: ReusableStream>(
    stream: S,
    handler: &Handler,
    stats: &GatewayStats,
    stop: &AtomicBool,
) {
    let mut reader = BufReader::with_capacity(MAX_HEAD_BYTES, stream);
    loop {
        match parse_from(&mut reader) {
            Ok(Some(req)) => {
                let resp = handler(&req);
                let keep = req.keep_alive && !stop.load(Ordering::Acquire);
                // Count before the write completes: clients may observe
                // the response (and /stats) before this thread runs again.
                stats.served.fetch_add(1, Ordering::Relaxed);
                if resp.write_conn(reader.get_mut(), keep).is_err() || !keep {
                    break;
                }
            }
            Ok(None) => break, // client closed
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break; // idle keep-alive connection timed out: just close
            }
            Err(_) => {
                // Unframeable request (or mid-request EOF): answer 400 on
                // a best-effort basis and end the connection.
                stats.parse_errors.fetch_add(1, Ordering::Relaxed);
                let s = reader.get_mut();
                s.discard_pending();
                let _ = Response::bad_request("malformed request").write_conn(s, false);
                break;
            }
        }
    }
}

/// Bounded connection queue feeding the worker pool.
struct ConnQueue {
    q: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    capacity: usize,
}

impl ConnQueue {
    /// Enqueue, or hand the stream back on overload so the caller can
    /// shed it with an explicit 429.
    fn push(&self, s: TcpStream) -> Result<(), TcpStream> {
        let mut q = self.q.lock().unwrap();
        if q.len() >= self.capacity {
            return Err(s);
        }
        q.push_back(s);
        self.cv.notify_one();
        Ok(())
    }

    fn pop(&self, stop: &AtomicBool) -> Option<TcpStream> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(s) = q.pop_front() {
                return Some(s);
            }
            if stop.load(Ordering::Acquire) {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(q, Duration::from_millis(50)).unwrap();
            q = guard;
        }
    }
}

/// Gateway request counters.
#[derive(Default)]
pub struct GatewayStats {
    pub accepted: AtomicU64,
    pub served: AtomicU64,
    pub shed: AtomicU64,
    pub parse_errors: AtomicU64,
}

/// The gateway server.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    pub stats: Arc<GatewayStats>,
}

/// Accept threads sharing the listener: enough to ride out a connection
/// burst without serializing behind one accept loop, few enough not to
/// thundering-herd a mostly-idle listener.
fn accept_pool_size(workers: usize) -> usize {
    workers.clamp(1, 4)
}

impl Server {
    /// Bind and serve `handler` with `workers` worker threads.  Pass port 0
    /// for an ephemeral port; the bound address is `addr()`.
    pub fn start(bind: &str, workers: usize, handler: Handler) -> std::io::Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(ConnQueue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            capacity: 1024,
        });
        let stats = Arc::new(GatewayStats::default());
        let mut threads = Vec::new();

        // Accept pool: each thread owns a clone of the shared non-blocking
        // listener; the kernel hands any given connection to exactly one.
        for _ in 0..accept_pool_size(workers) {
            let l = listener.try_clone()?;
            let (stop, queue, stats) = (stop.clone(), queue.clone(), stats.clone());
            threads.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    match l.accept() {
                        Ok((s, _)) => {
                            // The accepted fd can inherit the listener's
                            // non-blocking mode on some platforms.
                            let _ = s.set_nonblocking(false);
                            stats.accepted.fetch_add(1, Ordering::Relaxed);
                            if let Err(mut s) = queue.push(s) {
                                // Overload: shed with an explicit 429 so
                                // clients can back off instead of timing out.
                                // Off-thread: the drain below may block up
                                // to ~200 ms and must not stall accepts.
                                stats.shed.fetch_add(1, Ordering::Relaxed);
                                std::thread::spawn(move || {
                                    s.discard_pending();
                                    let _ = Response::too_many_requests("gateway queue full")
                                        .write_conn(&mut s, false);
                                });
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        // Worker pool: whole persistent connections, one at a time
        // (paper-faithful: CppCMS workers are per-connection).
        for _ in 0..workers.max(1) {
            let (stop, queue, stats, handler) =
                (stop.clone(), queue.clone(), stats.clone(), handler.clone());
            threads.push(std::thread::spawn(move || {
                while let Some(s) = queue.pop(&stop) {
                    let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
                    let _ = s.set_nodelay(true);
                    serve_stream(s, &handler, &stats, &stop);
                }
            }));
        }

        Ok(Server { addr, stop, threads, stats })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Persistent-connection HTTP client (keep-alive), for load generation —
/// the §Perf L3b optimization: amortizes the TCP connect across requests,
/// mirroring the paper's note that "re-using the same TCP/TLS connection
/// is a powerful optimization option".
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    addr: std::net::SocketAddr,
}

impl HttpClient {
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<HttpClient> {
        let s = TcpStream::connect(addr)?;
        s.set_read_timeout(Some(Duration::from_secs(30)))?;
        s.set_nodelay(true)?;
        Ok(HttpClient { reader: BufReader::new(s), addr })
    }

    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let result = self.request_inner(method, path, body);
        if result.is_err() {
            // Transparent reconnect once (server may have timed us out).
            *self = HttpClient::connect(self.addr)?;
            return self.request_inner(method, path, body);
        }
        result
    }

    fn request_inner(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        {
            let s = self.reader.get_mut();
            write!(
                s,
                "{method} {path} HTTP/1.1\r\nHost: coldfaas\r\nContent-Length: {}\r\n\r\n",
                body.len()
            )?;
            s.write_all(body)?;
            s.flush()?;
        }
        read_response(&mut self.reader)
    }
}

fn read_response(reader: &mut BufReader<TcpStream>) -> std::io::Result<(u16, Vec<u8>)> {
    let mut status_line = String::new();
    if reader.read_line(&mut status_line)? == 0 {
        return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server closed"));
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad content-length {:?}", v.trim()),
                )
            })?;
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

/// Blocking one-shot HTTP client (Connection: close) for tests/examples.
pub fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> std::io::Result<(u16, Vec<u8>)> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: coldfaas\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    s.write_all(body)?;
    s.flush()?;
    let mut reader = BufReader::new(s);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line"))?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().map_err(|_| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad content-length {:?}", v.trim()),
                )
            })?;
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        let handler: Handler = Arc::new(|req: &Request| match req.path.as_str() {
            "/noop" => Response::ok(""),
            p if p.starts_with("/echo") => Response::ok(req.body.clone()),
            _ => Response::not_found(),
        });
        Server::start("127.0.0.1:0", 4, handler).unwrap()
    }

    #[test]
    fn serves_noop() {
        let srv = echo_server();
        let (status, body) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
        assert_eq!(status, 200);
        assert!(body.is_empty());
        srv.shutdown();
    }

    #[test]
    fn echoes_post_body() {
        let srv = echo_server();
        let payload = b"1.5, 2.5, 3.5";
        let (status, body) = http_request(srv.addr(), "POST", "/echo", payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);
        srv.shutdown();
    }

    #[test]
    fn unknown_path_404() {
        let srv = echo_server();
        let (status, _) = http_request(srv.addr(), "GET", "/nope", b"").unwrap();
        assert_eq!(status, 404);
        srv.shutdown();
    }

    #[test]
    fn concurrent_requests() {
        let srv = echo_server();
        let addr = srv.addr();
        let handles: Vec<_> = (0..16)
            .map(|i| {
                std::thread::spawn(move || {
                    let body = format!("req-{i}");
                    let (status, got) =
                        http_request(addr, "POST", "/echo", body.as_bytes()).unwrap();
                    assert_eq!(status, 200);
                    assert_eq!(got, body.as_bytes());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.stats.served.load(Ordering::Relaxed), 16);
        srv.shutdown();
    }

    #[test]
    fn malformed_request_is_400_not_crash() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"\r\n\r\n").unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        // Server must keep serving afterwards.
        let (status, _) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
        assert_eq!(status, 200);
        srv.shutdown();
    }

    #[test]
    fn bad_method_token_is_400() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"G@T /noop HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("400"), "got: {text}");
        assert!(srv.stats.parse_errors.load(Ordering::Relaxed) >= 1);
        srv.shutdown();
    }

    #[test]
    fn duplicate_content_length_is_hard_400() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        // Two conflicting frames for the same request: classic smuggling
        // shape.  The parser must refuse, not pick one silently.
        s.write_all(b"POST /echo HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 3\r\n\r\nabc")
            .unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("400"), "got: {text}");
        assert!(srv.stats.parse_errors.load(Ordering::Relaxed) >= 1);
        // Server must keep serving afterwards.
        let (status, _) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
        assert_eq!(status, 200);
        srv.shutdown();
    }

    #[test]
    fn non_numeric_content_length_is_400() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"POST /echo HTTP/1.1\r\nContent-Length: +3\r\n\r\nabc").unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("400"), "got: {text}");
        srv.shutdown();
    }

    #[test]
    fn oversized_header_rejected() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        // A head that can never fit the stack buffer; the server must
        // 400 as soon as the buffer fills, not read forever.
        let mut junk = b"GET /noop HTTP/1.1\r\nX-Filler: ".to_vec();
        junk.resize(junk.len() + MAX_HEAD_BYTES + 1024, b'a');
        s.write_all(&junk).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("400"), "got: {text}");
        srv.shutdown();
    }

    #[test]
    fn oversized_body_rejected() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write!(s, "POST /echo HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 64 << 20).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("400"), "got: {text}");
        srv.shutdown();
    }

    #[test]
    fn truncated_body_is_400() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        // Promise 10 body bytes, deliver 3, half-close: the server sees
        // EOF mid-body and must answer 400 on the still-open write half.
        s.write_all(b"POST /echo HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("400"), "got: {text}");
        srv.shutdown();
    }

    #[test]
    fn overload_and_unavailable_status_lines() {
        let mut buf = Vec::new();
        Response::too_many_requests("slow down").write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.ends_with("slow down"));

        let mut buf = Vec::new();
        Response::unavailable("draining").write_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("Content-Length: 8\r\n"));
    }

    #[test]
    fn keep_alive_serves_many_requests_per_connection() {
        let srv = echo_server();
        let mut c = HttpClient::connect(srv.addr()).unwrap();
        for i in 0..20 {
            let body = format!("r{i}");
            let (status, got) = c.request("POST", "/echo", body.as_bytes()).unwrap();
            assert_eq!(status, 200);
            assert_eq!(got, body.as_bytes());
        }
        assert_eq!(srv.stats.served.load(Ordering::Relaxed), 20);
        // 20 requests over ONE accepted connection.
        assert_eq!(srv.stats.accepted.load(Ordering::Relaxed), 1);
        srv.shutdown();
    }

    #[test]
    fn pipelined_requests_all_served() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // Two back-to-back requests in one write: the head scan must not
        // swallow bytes of the second while framing the first.
        s.write_all(
            b"POST /echo HTTP/1.1\r\nContent-Length: 2\r\n\r\nr1\
              POST /echo HTTP/1.1\r\nContent-Length: 2\r\nConnection: close\r\n\r\nr2",
        )
        .unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("r1") && text.contains("r2"), "got: {text}");
        assert_eq!(srv.stats.served.load(Ordering::Relaxed), 2);
        srv.shutdown();
    }

    #[test]
    fn http_1_0_defaults_to_close() {
        let srv = echo_server();
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.write_all(b"GET /noop HTTP/1.0\r\n\r\n").unwrap();
        let mut buf = Vec::new();
        // read_to_end only returns if the server closes the connection.
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("200"), "got: {text}");
        assert!(text.contains("Connection: close"), "got: {text}");
        srv.shutdown();
    }

    #[test]
    fn connection_close_honored() {
        let srv = echo_server();
        // http_request sends Connection: close; server must close after 1.
        let (status, _) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
        assert_eq!(status, 200);
        assert_eq!(srv.stats.served.load(Ordering::Relaxed), 1);
        srv.shutdown();
    }

    #[test]
    fn shutdown_joins_threads() {
        let srv = echo_server();
        let addr = srv.addr();
        srv.shutdown();
        assert!(TcpStream::connect_timeout(&addr.into(), Duration::from_millis(200)).is_err()
            || http_request(addr, "GET", "/noop", b"").is_err());
    }
}
