//! HTTP gateway (S6): the real request frontend for the live coordinator,
//! mirroring the paper's CppCMS accept-thread + worker-pool architecture.

pub mod http;

pub use http::{http_request, parse_request, Handler, Request, Response, Server};
