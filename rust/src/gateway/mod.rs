//! HTTP gateway (S6/S29): the benchmark-grade request frontend for the
//! live planes — a multi-threaded accept pool over a shared non-blocking
//! listener, whole-connection keep-alive workers over a reusable stream
//! trait, and stack-buffer head parsing (no per-header heap allocation on
//! the hot path).  Mirrors the paper's CppCMS accept-thread + worker-pool
//! architecture; serves both the PJRT coordinator (S12) and the
//! simulation-mirroring live platform (S29, [`crate::live`]).

pub mod http;

pub use http::{
    http_request, parse_request, Handler, HttpClient, GatewayStats, Request, Response,
    ReusableStream, Server,
};
