//! Minimal JSON parser for the artifact manifest.
//!
//! The offline registry carries no serde, so this is a small recursive-
//! descent parser covering the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null) — enough for
//! `artifacts/manifest.json` and any config files, with real error
//! positions for debuggability.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (not emitted by aot.py).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn error_position_reported() {
        let e = Json::parse("[1, 2, oops]").unwrap_err();
        assert_eq!(e.pos, 7);
    }

    #[test]
    fn u64_accessor_rejects_fractions() {
        assert_eq!(Json::parse("3.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn round_trips_manifest_shape() {
        let doc = r#"{
          "format": 1,
          "functions": [
            {"name": "echo", "file": "echo.hlo.txt", "flops": 0,
             "inputs": [{"shape": [256], "dtype": "float32"}],
             "check": {"input": "sin037", "tol": 5e-4,
                       "outputs": [{"sum": 1.25, "l2": 8.0, "first": 0.0}]}}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        let fns = v.get("functions").unwrap().as_arr().unwrap();
        assert_eq!(fns[0].get("name").unwrap().as_str(), Some("echo"));
        assert_eq!(
            fns[0].get("check").unwrap().get("tol").unwrap().as_f64(),
            Some(5e-4)
        );
    }
}
