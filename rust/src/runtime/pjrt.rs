//! PJRT execution of the AOT artifacts (S11).
//!
//! Loads `artifacts/<fn>.hlo.txt` (HLO *text* — see aot.py for why not
//! serialized protos), compiles each once on the PJRT CPU client, and
//! executes them from the rust request path.  This is the "user function
//! body" of every live executor: python never runs here.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::{test_input, FunctionEntry, Manifest};

/// One compiled function.
pub struct LoadedFunction {
    exe: xla::PjRtLoadedExecutable,
    pub entry: FunctionEntry,
    /// One-time compile cost (the cold *deploy* cost, not per-request).
    pub compile_ms: f64,
}

/// The PJRT runtime: one CPU client, one compiled executable per function.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    loaded: HashMap<String, LoadedFunction>,
}

/// Result of verifying a function against its manifest check values.
#[derive(Debug, Clone)]
pub struct CheckReport {
    pub name: String,
    pub got_sum: f64,
    pub want_sum: f64,
    pub got_l2: f64,
    pub want_l2: f64,
    pub pass: bool,
}

impl Runtime {
    /// Load the manifest and compile every listed function.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir).context("loading artifact manifest")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut rt = Runtime { client, manifest: manifest.clone(), loaded: HashMap::new() };
        for entry in &manifest.functions {
            rt.compile_entry(entry)?;
        }
        Ok(rt)
    }

    /// Load the manifest but compile only `names` (faster cold start for
    /// single-function examples).
    pub fn load_only(dir: impl AsRef<std::path::Path>, names: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(&dir).context("loading artifact manifest")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut rt = Runtime { client, manifest: manifest.clone(), loaded: HashMap::new() };
        for name in names {
            let entry = manifest
                .get(name)
                .ok_or_else(|| anyhow!("function {name} not in manifest"))?
                .clone();
            rt.compile_entry(&entry)?;
        }
        Ok(rt)
    }

    fn compile_entry(&mut self, entry: &FunctionEntry) -> Result<()> {
        let path = self.manifest.hlo_path(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.loaded.insert(
            entry.name.clone(),
            LoadedFunction { exe, entry: entry.clone(), compile_ms },
        );
        Ok(())
    }

    /// Compile `name` from the manifest if it is not already loaded
    /// (used by the live deploy path).  Returns true if newly compiled.
    pub fn ensure_loaded(&mut self, name: &str) -> Result<bool> {
        if self.loaded.contains_key(name) {
            return Ok(false);
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("function {name} not in manifest"))?
            .clone();
        self.compile_entry(&entry)?;
        Ok(true)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.loaded.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    pub fn get(&self, name: &str) -> Option<&LoadedFunction> {
        self.loaded.get(name)
    }

    pub fn entry(&self, name: &str) -> Option<&FunctionEntry> {
        self.loaded.get(name).map(|l| &l.entry)
    }

    /// Execute `name` on a flat f32 payload (length must match the input
    /// spec).  Returns the flattened f32 output.
    pub fn execute(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let lf = self
            .loaded
            .get(name)
            .ok_or_else(|| anyhow!("function {name} not loaded"))?;
        let spec = &lf.entry.inputs[0];
        if input.len() != spec.elements() {
            return Err(anyhow!(
                "{name}: payload has {} elements, expects {}",
                input.len(),
                spec.elements()
            ));
        }
        let mut lit = xla::Literal::vec1(input);
        if spec.shape.len() > 1 {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            lit = lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))?;
        }
        let result = lf
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True; all workloads emit 1 output.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))
    }

    /// Execute and time one request; returns (output, wall ms).
    pub fn execute_timed(&self, name: &str, input: &[f32]) -> Result<(Vec<f32>, f64)> {
        let t0 = Instant::now();
        let out = self.execute(name, input)?;
        Ok((out, t0.elapsed().as_secs_f64() * 1e3))
    }

    /// Median execution time over `iters` runs on the check input.
    pub fn measure_exec_ms(&self, name: &str, iters: usize) -> Result<f64> {
        let entry = self.entry(name).ok_or_else(|| anyhow!("{name} not loaded"))?;
        let input = test_input(entry.inputs[0].elements());
        let mut times: Vec<f64> = Vec::with_capacity(iters);
        for _ in 0..iters.max(1) {
            let (_, ms) = self.execute_timed(name, &input)?;
            times.push(ms);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(times[times.len() / 2])
    }

    /// Verify a function's numerics against the manifest check values
    /// (computed by the jax oracle at AOT time) — the rust-side end of the
    /// python-free correctness chain.
    pub fn verify(&self, name: &str) -> Result<CheckReport> {
        let entry = self.entry(name).ok_or_else(|| anyhow!("{name} not loaded"))?.clone();
        let input = test_input(entry.inputs[0].elements());
        let out = self.execute(name, &input)?;
        let got_sum: f64 = out.iter().map(|&x| x as f64).sum();
        let got_l2: f64 = out.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let want = &entry.checks[0];
        // Tolerance scales with magnitude; manifest tol is relative-ish.
        let tol = entry.check_tol;
        let rel = |got: f64, want: f64| {
            if want.abs() < 1.0 {
                (got - want).abs()
            } else {
                (got / want - 1.0).abs()
            }
        };
        let pass = rel(got_sum, want.sum) < tol.max(1e-3) * 10.0
            && rel(got_l2, want.l2) < tol.max(1e-3) * 10.0;
        Ok(CheckReport {
            name: name.to_string(),
            got_sum,
            want_sum: want.sum,
            got_l2,
            want_l2: want.l2,
            pass,
        })
    }
}

#[cfg(test)]
mod tests {
    //! Unit tests run only when `make artifacts` has produced the AOT
    //! outputs; the integration suite (rust/tests/) requires them.
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn echo_round_trips() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load_only(&dir, &["echo"]).unwrap();
        let input = test_input(256);
        let out = rt.execute("echo", &input).unwrap();
        assert_eq!(out, input);
    }

    #[test]
    fn wrong_payload_size_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load_only(&dir, &["echo"]).unwrap();
        assert!(rt.execute("echo", &[1.0; 7]).is_err());
    }

    #[test]
    fn unknown_function_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load_only(&dir, &["echo"]).unwrap();
        assert!(rt.execute("nope", &[0.0; 256]).is_err());
    }

    #[test]
    fn all_functions_verify_against_oracle() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        for name in rt.names() {
            let rep = rt.verify(name).unwrap();
            assert!(
                rep.pass,
                "{name}: sum {} vs {}, l2 {} vs {}",
                rep.got_sum, rep.want_sum, rep.got_l2, rep.want_l2
            );
        }
    }
}
