//! API-identical stand-in for [`pjrt`](self) when the `pjrt` cargo
//! feature is off (the `xla` crate is not in the offline registry).
//!
//! `Runtime::load`/`load_only` always fail with a clear message, so the
//! coordinator, CLI and examples compile and report the missing backend
//! at runtime instead of the whole crate failing to build.  `Runtime` is
//! uninhabited: every method body is statically unreachable.

use anyhow::{anyhow, Result};

use super::manifest::{FunctionEntry, Manifest};

/// One compiled function (stub: never constructed).
pub struct LoadedFunction {
    pub entry: FunctionEntry,
    /// One-time compile cost (the cold *deploy* cost, not per-request).
    pub compile_ms: f64,
}

/// The PJRT runtime (stub: uninhabited, construction always fails).
pub struct Runtime {
    pub manifest: Manifest,
    never: std::convert::Infallible,
}

/// Result of verifying a function against its manifest check values.
#[derive(Debug, Clone)]
pub struct CheckReport {
    pub name: String,
    pub got_sum: f64,
    pub want_sum: f64,
    pub got_l2: f64,
    pub want_l2: f64,
    pub pass: bool,
}

fn unavailable() -> anyhow::Error {
    anyhow!(
        "PJRT runtime unavailable: coldfaas was built without the `pjrt` \
         feature (the `xla` crate is not in the offline registry). \
         The simulation stack (`coldfaas experiment ...`, `coldfaas policies`) \
         is fully functional without it."
    )
}

impl Runtime {
    pub fn load(_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn load_only(_dir: impl AsRef<std::path::Path>, _names: &[&str]) -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn ensure_loaded(&mut self, _name: &str) -> Result<bool> {
        match self.never {}
    }

    pub fn names(&self) -> Vec<&str> {
        match self.never {}
    }

    pub fn get(&self, _name: &str) -> Option<&LoadedFunction> {
        match self.never {}
    }

    pub fn entry(&self, _name: &str) -> Option<&FunctionEntry> {
        match self.never {}
    }

    pub fn execute(&self, _name: &str, _input: &[f32]) -> Result<Vec<f32>> {
        match self.never {}
    }

    pub fn execute_timed(&self, _name: &str, _input: &[f32]) -> Result<(Vec<f32>, f64)> {
        match self.never {}
    }

    pub fn measure_exec_ms(&self, _name: &str, _iters: usize) -> Result<f64> {
        match self.never {}
    }

    pub fn verify(&self, _name: &str) -> Result<CheckReport> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_backend() {
        let err = Runtime::load("/nonexistent").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        let err = Runtime::load_only("/nonexistent", &["echo"]).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
