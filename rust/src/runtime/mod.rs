//! PJRT runtime (S11): loads the AOT-compiled HLO artifacts and executes
//! them on the request path.  `json`/`manifest` are the (serde-free)
//! manifest layer; `pjrt` wraps the `xla` crate.
//!
//! The `xla` crate is not in the offline registry, so real PJRT execution
//! sits behind the `pjrt` cargo feature (which additionally requires
//! adding the dependency by hand).  Without it the live stack compiles
//! against an API-identical stub whose `Runtime::load` reports the
//! missing backend; the DES half of the crate is unaffected.

pub mod json;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use json::Json;
pub use manifest::{test_input, FunctionEntry, Manifest, TensorSpec};
pub use pjrt::{CheckReport, LoadedFunction, Runtime};

/// Default artifact directory relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Per-workload function execution medians (ms) measured on this testbed
/// via `coldfaas measure-exec` (PJRT CPU, single thread).  The DES
/// experiments use these when the artifacts aren't loaded; keep in sync
/// with EXPERIMENTS.md §Runtime-calibration.
pub fn static_exec_ms(name: &str) -> f64 {
    match name {
        "echo" => 0.023,
        "thumbnail" => 0.038,
        "checksum" => 0.951,
        "mlp" => 2.246,
        "transformer" => 11.7,
        _ => crate::fnplat::DEFAULT_EXEC_MS,
    }
}
