//! Typed view of `artifacts/manifest.json` (emitted by python/compile/aot.py).

use std::path::{Path, PathBuf};

use super::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Oracle-computed check values over the deterministic test input.
#[derive(Clone, Debug, PartialEq)]
pub struct OutputCheck {
    pub sum: f64,
    pub l2: f64,
    pub first: f64,
}

#[derive(Clone, Debug)]
pub struct FunctionEntry {
    pub name: String,
    pub file: String,
    pub doc: String,
    pub flops: u64,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub check_tol: f64,
    pub checks: Vec<OutputCheck>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub functions: Vec<FunctionEntry>,
}

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Parse(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "manifest io: {e}"),
            ManifestError::Parse(m) => write!(f, "manifest parse: {m}"),
        }
    }
}

impl std::error::Error for ManifestError {}

fn specs(v: &Json, key: &str) -> Result<Vec<TensorSpec>, ManifestError> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| ManifestError::Parse(format!("missing {key}[]")))?;
    arr.iter()
        .map(|s| {
            let shape = s
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| ManifestError::Parse("missing shape".into()))?
                .iter()
                .map(|d| d.as_u64().map(|u| u as usize))
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| ManifestError::Parse("bad shape dim".into()))?;
            let dtype = s
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError::Parse("missing dtype".into()))?
                .to_string();
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(ManifestError::Io)?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, ManifestError> {
        let root = Json::parse(text).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let fns = root
            .get("functions")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Parse("missing functions[]".into()))?;
        let mut functions = Vec::new();
        for f in fns {
            let get_str = |k: &str| -> Result<String, ManifestError> {
                f.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| ManifestError::Parse(format!("missing {k}")))
            };
            let check = f
                .get("check")
                .ok_or_else(|| ManifestError::Parse("missing check".into()))?;
            let checks = check
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| ManifestError::Parse("missing check.outputs".into()))?
                .iter()
                .map(|c| {
                    Ok(OutputCheck {
                        sum: c.get("sum").and_then(Json::as_f64).ok_or_else(|| {
                            ManifestError::Parse("missing check sum".into())
                        })?,
                        l2: c.get("l2").and_then(Json::as_f64).unwrap_or(f64::NAN),
                        first: c.get("first").and_then(Json::as_f64).unwrap_or(f64::NAN),
                    })
                })
                .collect::<Result<Vec<_>, ManifestError>>()?;
            functions.push(FunctionEntry {
                name: get_str("name")?,
                file: get_str("file")?,
                doc: f.get("doc").and_then(Json::as_str).unwrap_or("").to_string(),
                flops: f.get("flops").and_then(Json::as_u64).unwrap_or(0),
                inputs: specs(f, "inputs")?,
                outputs: specs(f, "outputs")?,
                check_tol: check.get("tol").and_then(Json::as_f64).unwrap_or(1e-3),
                checks,
            });
        }
        Ok(Manifest { dir, functions })
    }

    pub fn get(&self, name: &str) -> Option<&FunctionEntry> {
        self.functions.iter().find(|f| f.name == name)
    }

    pub fn hlo_path(&self, entry: &FunctionEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

/// The deterministic check vector mirrored from `model.test_input`:
/// flat[i] = sin(0.37 * i) * 0.5, f32.
pub fn test_input(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((0.37 * i as f64).sin() * 0.5) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "format": 1,
      "functions": [
        {"name": "echo", "file": "echo.hlo.txt", "doc": "identity",
         "flops": 0,
         "inputs": [{"shape": [256], "dtype": "float32"}],
         "outputs": [{"shape": [256], "dtype": "float32"}],
         "check": {"input": "sin037", "tol": 0.0005,
                   "outputs": [{"sum": 1.0, "l2": 2.0, "first": 0.0}]}},
        {"name": "mlp", "file": "mlp.hlo.txt", "doc": "inference",
         "flops": 4194304,
         "inputs": [{"shape": [8, 256], "dtype": "float32"}],
         "outputs": [{"shape": [8, 256], "dtype": "float32"}],
         "check": {"input": "sin037", "tol": 0.0005,
                   "outputs": [{"sum": -3.0, "l2": 4.0, "first": 0.1}]}}
      ]
    }"#;

    #[test]
    fn parses_two_functions() {
        let m = Manifest::parse(DOC, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.functions.len(), 2);
        let mlp = m.get("mlp").unwrap();
        assert_eq!(mlp.flops, 4_194_304);
        assert_eq!(mlp.inputs[0].shape, vec![8, 256]);
        assert_eq!(mlp.inputs[0].elements(), 2048);
        assert_eq!(m.hlo_path(mlp), PathBuf::from("/tmp/mlp.hlo.txt"));
    }

    #[test]
    fn missing_function_is_none() {
        let m = Manifest::parse(DOC, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse(r#"{"functions": [{"name": "x"}]}"#, PathBuf::new()).is_err());
    }

    #[test]
    fn test_input_matches_python_formula() {
        let v = test_input(4);
        assert_eq!(v[0], 0.0);
        assert!((v[1] as f64 - (0.37f64).sin() * 0.5).abs() < 1e-7);
    }

    #[test]
    fn scalar_output_elements_is_one() {
        let t = TensorSpec { shape: vec![], dtype: "float32".into() };
        assert_eq!(t.elements(), 1);
    }
}
