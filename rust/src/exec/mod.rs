//! Executor units for the live stack (S10): apply a startup model in real
//! time (scaled sleeps), then run the function body through PJRT.
//!
//! The realtime path intentionally models only the *per-start* latency of
//! the sandbox pipeline — kernel-lock contention under parallel starts is
//! the DES's job (`sim::Engine`); here the host OS provides real
//! contention for the actual PJRT compute.

use std::time::Duration;

use crate::sim::{Dist, Rng, Step, StepKind};

/// A startup-latency model applied with real sleeps.
#[derive(Clone)]
pub struct RealtimeStartup {
    steps: Vec<Step>,
    /// 1.0 = model-faithful sleeps; 0.0 = skip sleeps (unit tests);
    /// 0.1 = 10x-compressed demo runs.
    pub time_scale: f64,
}

impl RealtimeStartup {
    pub fn new(steps: Vec<Step>, time_scale: f64) -> RealtimeStartup {
        RealtimeStartup { steps, time_scale }
    }

    /// Sample the total modeled startup for one request (ns, unscaled).
    pub fn sample_ns(&self, rng: &mut Rng) -> u64 {
        self.steps
            .iter()
            .map(|s| match s.kind {
                StepKind::Effect(_) | StepKind::Decision(_) => 0,
                StepKind::Disk(bytes) => (bytes as f64 / 1.2e9 * 1e9) as u64,
                _ => s.dur.sample(rng),
            })
            .sum()
    }

    /// Sleep out one sampled startup; returns the modeled (unscaled) ns.
    pub fn apply(&self, rng: &mut Rng) -> u64 {
        let ns = self.sample_ns(rng);
        let scaled = (ns as f64 * self.time_scale) as u64;
        if scaled > 0 {
            std::thread::sleep(Duration::from_nanos(scaled));
        }
        ns
    }

    /// Nominal (median-sum) startup in ms.
    pub fn nominal_ms(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| match s.kind {
                StepKind::Disk(bytes) => bytes as f64 / 1.2e9 * 1e3,
                _ => s.dur.median_ns() / 1e6,
            })
            .sum()
    }

    /// Instant completion (for tests) while keeping the model shape.
    pub fn instant() -> RealtimeStartup {
        RealtimeStartup { steps: vec![Step::delay("none", Dist::Const(0.0))], time_scale: 0.0 }
    }
}

/// The three startup pipelines of a driver — cold, warm, specialized —
/// each capped with the execution step, as realtime models.
///
/// This is the live plane's mirror of the DES dispatch tail
/// (`platform/sim.rs`): a cold claim pays the full cold pipeline, a warm
/// claim the warm-invoke steps, and a specialized claim (S23: runtime
/// warm, function state cold) the warm steps plus the specialization
/// pipeline.  E18 `livecheck` relies on both planes sampling from these
/// same distributions, so the composition here must stay in lock-step
/// with `dispatch_tail`.
pub fn heat_pipelines(
    kind: crate::fnplat::DriverKind,
    exec_ms: f64,
    time_scale: f64,
) -> [RealtimeStartup; 3] {
    let exec = crate::fnplat::exec_step(exec_ms);
    let mut cold = kind.cold_start_steps();
    cold.push(exec);
    let mut warm = kind.warm_invoke_steps();
    warm.push(exec);
    let mut spec = kind.warm_invoke_steps();
    spec.extend(kind.specialize_steps());
    spec.push(exec);
    [
        RealtimeStartup::new(cold, time_scale),
        RealtimeStartup::new(warm, time_scale),
        RealtimeStartup::new(spec, time_scale),
    ]
}

/// Payload codec: request bodies are either empty (use the deterministic
/// check input) or ASCII floats separated by commas/whitespace.
pub fn parse_payload(body: &[u8], expected: usize) -> Result<Vec<f32>, String> {
    if body.is_empty() {
        return Ok(crate::runtime::test_input(expected));
    }
    let text = std::str::from_utf8(body).map_err(|_| "payload is not utf-8".to_string())?;
    let vals: Result<Vec<f32>, _> = text
        .split(|c: char| c == ',' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .map(str::parse::<f32>)
        .collect();
    let vals = vals.map_err(|e| format!("bad float in payload: {e}"))?;
    if vals.len() != expected {
        return Err(format!("payload has {} values, function expects {expected}", vals.len()));
    }
    Ok(vals)
}

/// Summarize an output tensor for the HTTP reply (full tensors can be
/// hundreds of KB; the summary keeps the serving path cheap and still
/// verifiable against the manifest checks).
pub fn summarize_output(out: &[f32]) -> (f64, f64, Vec<f32>) {
    let sum: f64 = out.iter().map(|&x| x as f64).sum();
    let l2: f64 = out.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    let head: Vec<f32> = out.iter().take(8).copied().collect();
    (sum, l2, head)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::virt::Tech;

    #[test]
    fn sample_matches_nominal_roughly() {
        let m = RealtimeStartup::new(Tech::IncludeOsHvt.pipeline(), 0.0);
        let mut rng = Rng::new(1);
        let n = 2000;
        let mean: f64 = (0..n).map(|_| m.sample_ns(&mut rng) as f64 / 1e6).sum::<f64>() / n as f64;
        let nominal = m.nominal_ms();
        assert!((mean / nominal - 1.0).abs() < 0.1, "mean {mean} vs nominal {nominal}");
    }

    #[test]
    fn zero_scale_does_not_sleep() {
        let m = RealtimeStartup::new(Tech::DockerRunc.pipeline(), 0.0);
        let mut rng = Rng::new(2);
        let t0 = std::time::Instant::now();
        m.apply(&mut rng);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn scaled_sleep_is_proportional() {
        let m = RealtimeStartup::new(
            vec![Step::delay("d", Dist::const_ms(100.0))],
            0.05, // 5 ms real
        );
        let mut rng = Rng::new(3);
        let t0 = std::time::Instant::now();
        let modeled = m.apply(&mut rng);
        let real = t0.elapsed().as_millis();
        assert_eq!(modeled, 100_000_000);
        assert!((4..60).contains(&(real as i64)), "slept {real} ms");
    }

    #[test]
    fn empty_payload_yields_test_input() {
        let p = parse_payload(b"", 4).unwrap();
        assert_eq!(p, crate::runtime::test_input(4));
    }

    #[test]
    fn parses_ascii_floats() {
        let p = parse_payload(b"1.5, -2.0  3\n4e-1", 4).unwrap();
        assert_eq!(p, vec![1.5, -2.0, 3.0, 0.4]);
    }

    #[test]
    fn wrong_arity_rejected() {
        assert!(parse_payload(b"1,2,3", 4).is_err());
        assert!(parse_payload(b"1,2,x,4", 4).is_err());
        assert!(parse_payload(&[0xff, 0xfe], 2).is_err());
    }

    #[test]
    fn heat_pipelines_order_and_composition() {
        use crate::fnplat::DriverKind;
        let [cold, warm, spec] = heat_pipelines(DriverKind::DockerWarm, 0.8, 0.0);
        // Docker nominals (DESIGN.md §2): cold ≫ specialized ≫ warm, and
        // each pipeline carries the 0.8 ms exec step on top.
        assert!(cold.nominal_ms() > spec.nominal_ms());
        assert!(spec.nominal_ms() > warm.nominal_ms());
        let kind = DriverKind::DockerWarm;
        let warm_only: f64 =
            kind.warm_invoke_steps().iter().map(|s| s.dur.median_ns() / 1e6).sum();
        assert!((warm.nominal_ms() - warm_only - 0.8).abs() < 1e-9);
        let spec_extra: f64 =
            kind.specialize_steps().iter().map(|s| s.dur.median_ns() / 1e6).sum();
        assert!((spec.nominal_ms() - warm_only - spec_extra - 0.8).abs() < 1e-9);
    }

    #[test]
    fn summary_values() {
        let (sum, l2, head) = summarize_output(&[3.0, 4.0]);
        assert_eq!(sum, 7.0);
        assert!((l2 - 5.0).abs() < 1e-9);
        assert_eq!(head, vec![3.0, 4.0]);
    }
}
