//! coldfaas — cold-start-only FaaS with unikernel-style executors.
//!
//! Subcommands:
//!   experiment <name>|all   regenerate a paper figure/table (DESIGN.md §5)
//!   policies                keep-alive policy lab (E12): latency-vs-waste frontier
//!   fleet                   cluster-scale fleet sweep (E13): policy x scheduler x driver
//!   chaos                   fault-injection sweep (E14): the fleet under node crashes
//!   planet                  planet sweep (E15): 256 nodes, 10k fns, millions of requests
//!   sharing                 universal-worker sharing sweep (E16): shared warm pools
//!   hyperplanet             sharded sweep (E17): 1024 nodes, 10k fns, parallel cells
//!   trace                   replay one experiment cell with lifecycle tracing on
//!   livecheck               E18 cross-validation: one trace through the DES and
//!                           the live stack, measured classes banded vs prediction
//!   loadgen                 open-loop load generator against a live gateway
//!   compare                 bench-regression gate: diff two BENCH_*.json reports
//!   lint                    determinism audit: run detlint over rust/src (DESIGN.md S28)
//!   serve                   start the live platform (HTTP + PJRT)
//!   invoke <fn>             one-shot local invocation through the stack
//!   verify                  check every AOT artifact against its oracle
//!   measure-exec            PJRT execution medians for the workload ladder
//!   list                    list deployable functions

// The CLI binary is a wall-clock island (detlint.allow): report wall_s
// fields, serve-loop polling, and live-stack timing all read real time.
#![allow(clippy::disallowed_methods)]

use std::io::Write;

use coldfaas::cli::Args;
use coldfaas::coordinator::{Config, Coordinator, SchedMode};
use coldfaas::experiments::{self, ExpConfig};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let code = match args.subcommand.as_str() {
        "experiment" => cmd_experiment(&args),
        "policies" => cmd_policies(&args),
        "fleet" => cmd_fleet(&args),
        "chaos" => cmd_chaos(&args),
        "planet" => cmd_planet(&args),
        "sharing" => cmd_sharing(&args),
        "hyperplanet" => cmd_hyperplanet(&args),
        "trace" => cmd_trace(&args),
        "livecheck" => cmd_livecheck(&args),
        "loadgen" => cmd_loadgen(&args),
        "compare" => cmd_compare(&args),
        "lint" => cmd_lint(&args),
        "serve" => cmd_serve(&args),
        "invoke" => cmd_invoke(&args),
        "verify" => cmd_verify(&args),
        "measure-exec" => cmd_measure_exec(&args),
        "list" => cmd_list(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{HELP}");
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
coldfaas — cold-start-only FaaS (reproduction of 'Cooling Down FaaS', 2022)

USAGE: coldfaas <subcommand> [options]

  experiment <fig1|fig2|fig3|fig4|table1|decompose|images|complexity|waste|distance|scaleout|policies|fleet|chaos|planet|sharing|hyperplanet|all>
      --requests N          requests per cell (default 10000; paper value)
      --parallelism LIST    e.g. 1,5,10,20,40 (default)
      --seed N              deterministic seed
      --quick               reduced load for smoke runs
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report (BENCH_*.json)

  policies                  keep-alive policy lab (E12): every lifecycle
                            policy x driver over a multi-tenant Zipf trace
      --functions N         distinct functions (default 1000)
      --rps F               aggregate offered load (default sized from --requests)
      --duration S          virtual trace seconds (default sized from --requests)
      --zipf S              popularity exponent (default 1.1)
      --seed N              deterministic seed
      --quick               reduced load for smoke runs
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  fleet                     cluster-scale fleet sweep (E13): lifecycle
                            policy x placement scheduler x driver over a
                            1000-function Zipf trace on an N-node cluster
      --nodes N             cluster size, 1..=1024 (default 8)
      --cores N             cores per node (default 8)
      --functions N         distinct functions (default 1000)
      --rps F               aggregate offered load (default sized from --requests)
      --duration S          virtual trace seconds (default sized from --requests)
      --zipf S              popularity exponent (default 1.1)
      --seed N              deterministic seed
      --quick               reduced load for smoke runs
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  chaos                     fault-injection sweep (E14): the E13 fleet under
                            a scripted fault schedule — staggered node
                            crashes (warm pools drained, in-flight requests
                            killed and retried, image caches flushed on
                            restart, 2x straggler starts) plus a fabric
                            brown-out; every cell is paired with a
                            fault-free baseline over the same trace
      --nodes N             cluster size, 2..=1024 (default 8)
      --cores N             cores per node (default 8)
      --functions N         distinct functions (default 1000)
      --rps F               aggregate offered load (default sized from --requests)
      --duration S          virtual trace seconds (default sized from --requests)
      --zipf S              popularity exponent (default 1.1)
      --seed N              deterministic seed
      --quick               reduced load for smoke runs
      --timeseries          sample interval telemetry (cold fraction, pool
                            occupancy, ...) on the two focus cells
      --trace FILE          also write a Chrome trace_event capture of the
                            flagship cell (docker+fixed-600s+least-loaded)
      --trace-window        keep only trace events inside disruption windows
      --trace-capacity N    ring-buffer cap on retained trace events (0 = all)
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  planet                    planet sweep (E15): the cold-only frontier
                            claim at fleet scale — 256 nodes, 10 000
                            functions, a multi-million-request streamed
                            Zipf trace per cell, plus simulator
                            events/s (the DES hot-path metric)
      --nodes N             cluster size, 1..=1024 (default 256)
      --cores N             cores per node (default 8)
      --functions N         distinct functions (default 10000)
      --rps F               aggregate offered load (default sized from --requests)
      --duration S          virtual trace seconds (default 300)
      --zipf S              popularity exponent (default 1.1)
      --seed N              deterministic seed
      --quick               reduced trace (same 256-node cluster)
      --timeseries          sample interval telemetry on every cell
      --checkpoint DIR      write one snapshot file per cell at virtual-time
                            barriers (10 virtual seconds); a killed run
                            relaunched with --resume picks up from there
      --resume DIR          resume cells from their snapshot files in DIR,
                            byte-identical to an uninterrupted run
                            (implies --checkpoint DIR)
      --state-hash          fold the rolling per-barrier state hash without
                            writing snapshots (pinned by the regression
                            suite; observationally pure)
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  sharing                   universal-worker sharing sweep (E16): the E13
                            fleet against runtime-keyed shared warm pools
                            (UniversalPool policy) across sharing mode x
                            specialization cost, reporting the break-even
                            specialization cost vs cold-only IncludeOS
      --nodes N             cluster size, 1..=1024 (default 8)
      --cores N             cores per node (default 8)
      --runtimes N          runtime families functions hash onto (default 4)
      --target N            universal workers targeted per bucket (default 8)
      --spec-costs LIST     specialization costs in ms, e.g. 1,4,16,64
                            (default; checks assume a cheap-to-dear sweep)
      --functions N         distinct functions (default 1000)
      --rps F               aggregate offered load (default sized from --requests)
      --duration S          virtual trace seconds (default sized from --requests)
      --zipf S              popularity exponent (default 1.1)
      --seed N              deterministic seed
      --quick               reduced load for smoke runs
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  hyperplanet               sharded sweep (E17): the E15 grid at 1024 nodes
                            with the S26 sharded accounting plane (per-shard
                            partials merged bit-identically at any shard
                            count) and cells running in parallel on the
                            sweep runner; aggregate events/s is the gated
                            throughput headline
      --nodes N             cluster size, 1..=1024 (default 1024)
      --cores N             cores per node (default 8)
      --shards N            accounting shards per cell (default 8; any
                            value yields byte-identical reports)
      --functions N         distinct functions (default 10000)
      --rps F               aggregate offered load (default sized from --requests)
      --duration S          virtual trace seconds (default 600)
      --zipf S              popularity exponent (default 1.1)
      --seed N              deterministic seed
      --quick               reduced trace (same 1024-node cluster)
      --timeseries          sample interval telemetry on every cell
      --checkpoint DIR      write one snapshot file per cell at virtual-time
                            barriers (10 virtual seconds); a killed run
                            relaunched with --resume picks up from there
      --resume DIR          resume cells from their snapshot files in DIR,
                            byte-identical to an uninterrupted run
                            (implies --checkpoint DIR)
      --state-hash          fold the rolling per-barrier state hash without
                            writing snapshots (pinned by the regression
                            suite; observationally pure)
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  trace [cell]              replay one experiment cell with the observability
                            layer armed and write a Chrome trace_event file
                            (load it in chrome://tracing or
                            https://ui.perfetto.dev); default cell:
                            docker+fixed-600s+least-loaded
      --experiment NAME     chaos (cells driver+policy+scheduler) or
                            planet (cells driver+policy); default chaos
      --baseline            replay the dry fault-free leg (chaos only)
      --trace FILE          trace output path (default trace.json)
      --trace-window        keep only trace events inside disruption windows
      --trace-capacity N    ring-buffer cap on retained trace events (0 = all)
      --timeseries          also sample interval telemetry into the report
      --nodes/--cores/--functions/--rps/--duration/--zipf/--seed/--quick
                            grid shape, as for chaos/planet
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  livecheck                 E18 sim-vs-live cross-validation (DESIGN.md S29):
                            replay one deterministic tenant trace through the
                            DES *and* the live HTTP stack, classify measured
                            requests warm/specialized/cold from response
                            annotations, and band each class's measured p50
                            against the DES prediction; the sim leg is
                            byte-identical per seed, the live leg is
                            band-gated (see EXPERIMENTS.md, 'Simulation vs.
                            live measurement')
      --quick               CI cell: ~240 requests over 8 s (default: ~1200
                            over 20 s)
      --scale F             real seconds per modeled second on the live leg
                            (default 1.0; smaller compresses the replay and
                            proportionally widens the bands)
      --seed N              deterministic seed for trace and startup samples
      --out FILE            also append the report to FILE
      --json FILE           write a machine-readable report

  loadgen                   open-loop load generator: replay a deterministic
                            tenant trace against a live gateway over
                            keep-alive connections, measuring latency from
                            each request's *scheduled* arrival
                            (coordinated-omission-free); self-hosts an S29
                            live platform unless --target is given
      --target ADDR         existing gateway to drive (default: self-host)
      --functions N         distinct functions in the trace (default 24)
      --rps F               aggregate offered load (default 50)
      --duration S          trace seconds (default 10)
      --scale F             pacing scale (default 1.0; 0 = as fast as the
                            senders can go)
      --senders N           keep-alive sender connections (default 8)
      --zipf S              popularity exponent (default 1.1)
      --seed N              deterministic trace seed

  compare <run.json> <baseline.json>
                            bench-regression gate over two machine-readable
                            reports: paper-check booleans must match exactly,
                            latency/waste metrics within --tol, wall-clock
                            informational, events/s gated one-sidedly against
                            regressions; exit 1 on drift
      --tol F               relative tolerance for metrics (default 0.10)
      --deny-bootstrap      fail (exit 1) when the baseline is still the
                            bootstrap placeholder instead of passing with a
                            notice — CI uses this so an unarmed gate is loud
      --out FILE            also append the diff to FILE

  lint                      determinism audit (detlint, DESIGN.md S28): scan
                            rust/src for wall-clock reads (DL001), HashMap
                            iteration in the DES core (DL002), lenient parses
                            (DL003), mutating debug_assert! (DL004), and
                            snapshot-codec field omissions (DL005); findings
                            suppressed via `// detlint: allow(..)` pragmas or
                            the committed rust/detlint.allow; exit 1 on any
                            unsuppressed finding
      --root DIR            crate root to scan (default: this crate)
      --json FILE           write a machine-readable report

  serve
      --bind ADDR           default 127.0.0.1:8080
      --mode cold|warm      scheduler (default cold)
      --time-scale F        startup-model sleep scale (default 1.0)
      --engines N           PJRT engine threads (default 1)
      --workers N           gateway worker threads (default 20)
      --functions a,b       compile only these (default: all)

  invoke <fn>  [--payload '1,2,3'] [--mode cold|warm] [--time-scale F]
  verify       [--artifacts DIR]
  measure-exec [--iters N]
  list
";

/// Strict shared experiment config: malformed numeric flags are a hard
/// CLI error (exit 2), never a silent fall-back to the default.
fn exp_config(args: &Args) -> Result<ExpConfig, String> {
    let mut cfg = if args.has_flag("quick") { ExpConfig::quick() } else { ExpConfig::default() };
    cfg.requests = args.try_get_u64("requests", cfg.requests)?;
    cfg.parallelisms = args.try_get_u32_list("parallelism", &cfg.parallelisms)?;
    cfg.seed = args.try_get_u64("seed", cfg.seed)?;
    Ok(cfg)
}

/// Print a usage error and return the CLI's usage exit code.
fn usage_error(subcommand: &str, e: &str) -> i32 {
    eprintln!("{subcommand}: {e}");
    2
}

/// Append rendered report text to the `--out` file, if requested.
fn append_out(args: &Args, rendered: &str) {
    if let Some(path) = args.get("out") {
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            let _ = f.write_all(rendered.as_bytes());
        }
    }
}

/// Write the per-experiment JSON entries to the `--json` file, if
/// requested (machine-readable mirror of the rendered reports, the format
/// bench trajectory files record).
fn write_json(args: &Args, entries: &[String], total_wall_s: f64) -> bool {
    let Some(path) = args.get("json") else { return true };
    let doc = coldfaas::report::json_document(entries, total_wall_s);
    match std::fs::write(path, doc) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("write --json {path}: {e}");
            false
        }
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let Some(name) = args.positional.first() else {
        eprintln!("usage: coldfaas experiment <name>|all");
        return 2;
    };
    let cfg = match exp_config(args) {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("experiment", &e),
    };
    let names: Vec<&str> = if name == "all" {
        experiments::ALL_EXPERIMENTS.to_vec()
    } else {
        vec![name.as_str()]
    };
    let mut all_pass = true;
    let mut rendered = String::new();
    let mut json_entries = Vec::new();
    let t_all = std::time::Instant::now();
    for n in names {
        let t0 = std::time::Instant::now();
        match experiments::by_name(n, &cfg) {
            Some(report) => {
                let wall = t0.elapsed().as_secs_f64();
                let txt = report.render();
                print!("{txt}");
                println!("  ({n} in {wall:.1} s)");
                rendered.push_str(&txt);
                json_entries.push(report.to_json(n, wall));
                all_pass &= report.all_pass();
            }
            None => {
                eprintln!("unknown experiment '{n}'");
                return 2;
            }
        }
    }
    append_out(args, &rendered);
    all_pass &= write_json(args, &json_entries, t_all.elapsed().as_secs_f64());
    if all_pass {
        0
    } else {
        1
    }
}

/// Render, print, and persist one report produced by a dedicated
/// subcommand; returns the process exit code.
fn finish_report(args: &Args, id: &str, report: coldfaas::report::Report, wall_s: f64) -> i32 {
    let txt = report.render();
    print!("{txt}");
    println!("  ({id} in {wall_s:.1} s)");
    append_out(args, &txt);
    let json_ok = write_json(args, &[report.to_json(id, wall_s)], wall_s);
    if report.all_pass() && json_ok {
        0
    } else {
        1
    }
}

/// Apply the shared tenant-shape flags (`--functions/--rps/--duration/
/// --zipf`) strictly, then validate positivity.
fn tenant_flags(
    args: &Args,
    tenant: &mut coldfaas::workload::tenants::TenantConfig,
) -> Result<(), String> {
    tenant.functions = args.try_get_u32("functions", tenant.functions)?;
    tenant.total_rps = args.try_get_f64("rps", tenant.total_rps)?;
    tenant.duration_s = args.try_get_f64("duration", tenant.duration_s)?;
    tenant.zipf_exponent = args.try_get_f64("zipf", tenant.zipf_exponent)?;
    if tenant.functions == 0 || tenant.total_rps <= 0.0 || tenant.duration_s <= 0.0 {
        return Err("--functions, --rps and --duration must be positive".to_string());
    }
    Ok(())
}

fn cmd_policies(args: &Args) -> i32 {
    use coldfaas::experiments::policies::{e12_config, policies_with};
    let cfg = exp_config(args).and_then(|base| {
        let mut cfg = e12_config(&base);
        tenant_flags(args, &mut cfg.tenant)?;
        Ok(cfg)
    });
    let cfg = match cfg {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("policies", &e),
    };
    let t0 = std::time::Instant::now();
    let report = policies_with(&cfg);
    finish_report(args, "policies", report, t0.elapsed().as_secs_f64())
}

fn cmd_fleet(args: &Args) -> i32 {
    use coldfaas::experiments::fleet::{fleet_config, fleet_with};
    let cfg = exp_config(args).and_then(|base| {
        let mut cfg = fleet_config(&base);
        cfg.nodes = args.try_get_u64("nodes", cfg.nodes as u64)? as usize;
        cfg.cores_per_node = args.try_get_u32("cores", cfg.cores_per_node)?;
        tenant_flags(args, &mut cfg.tenant)?;
        if cfg.nodes == 0 || cfg.nodes > coldfaas::platform::MAX_NODES {
            return Err(format!("--nodes must be in 1..={}", coldfaas::platform::MAX_NODES));
        }
        if cfg.cores_per_node == 0 {
            return Err("--cores must be positive".to_string());
        }
        Ok(cfg)
    });
    let cfg = match cfg {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("fleet", &e),
    };
    let t0 = std::time::Instant::now();
    let report = fleet_with(&cfg);
    finish_report(args, "fleet", report, t0.elapsed().as_secs_f64())
}

/// Parse the S27 checkpoint flags shared by the heavy grids:
/// `--checkpoint DIR` writes per-cell snapshots, `--resume DIR` implies
/// `--checkpoint DIR` and restores cells whose file already exists, and
/// `--state-hash` folds the rolling chain without writing anything.  The
/// directory is created eagerly so a cell's first barrier cannot fail
/// mid-grid on a missing path.
fn checkpoint_flags(args: &Args) -> Result<coldfaas::experiments::CheckpointPlan, String> {
    let mut plan = coldfaas::experiments::CheckpointPlan {
        state_hash: args.has_flag("state-hash"),
        ..Default::default()
    };
    if let Some(dir) = args.get("resume") {
        plan.dir = Some(dir.to_string());
        plan.resume = true;
    }
    if let Some(dir) = args.get("checkpoint") {
        if plan.dir.as_deref().is_some_and(|d| d != dir) {
            return Err("--checkpoint and --resume must name the same directory".to_string());
        }
        plan.dir = Some(dir.to_string());
    }
    if let Some(dir) = &plan.dir {
        std::fs::create_dir_all(dir).map_err(|e| format!("--checkpoint {dir}: {e}"))?;
    }
    Ok(plan)
}

/// ~96 telemetry samples across the virtual horizon (the same sampling
/// density the chaos focus cells use internally).
fn telemetry_interval_ns(duration_s: f64) -> u64 {
    ((duration_s * 1e9) / 96.0).ceil().max(1.0) as u64
}

/// Build the tracing config from the shared `--trace-window` /
/// `--trace-capacity` flags (telemetry is wired separately).
fn trace_obs(args: &Args) -> Result<coldfaas::obs::ObsConfig, String> {
    Ok(coldfaas::obs::ObsConfig {
        trace: true,
        trace_capacity: args.try_get_u64("trace-capacity", 0)? as usize,
        trace_window_only: args.has_flag("trace-window"),
        telemetry_interval_ns: 0,
    })
}

/// Write a captured Chrome trace to `path`; false on I/O failure.
fn write_trace(path: &str, out: &coldfaas::experiments::replay::ReplayOutcome) -> bool {
    let json = out.result.trace_json.as_deref().unwrap_or_default();
    match std::fs::write(path, json) {
        Ok(()) => {
            println!("  trace of cell {} written to {path} ({} bytes)", out.label, json.len());
            true
        }
        Err(e) => {
            eprintln!("write --trace {path}: {e}");
            false
        }
    }
}

fn cmd_chaos(args: &Args) -> i32 {
    use coldfaas::experiments::chaos::{chaos_config, chaos_with};
    use coldfaas::experiments::replay::{replay_chaos_cell, DEFAULT_CELL};
    let cfg = exp_config(args).and_then(|base| {
        let mut cfg = chaos_config(&base);
        cfg.nodes = args.try_get_u64("nodes", cfg.nodes as u64)? as usize;
        cfg.cores_per_node = args.try_get_u32("cores", cfg.cores_per_node)?;
        cfg.timeseries = args.has_flag("timeseries");
        tenant_flags(args, &mut cfg.tenant)?;
        if cfg.nodes < 2 || cfg.nodes > coldfaas::platform::MAX_NODES {
            return Err(format!(
                "--nodes must be in 2..={} (a node must survive the fault plan)",
                coldfaas::platform::MAX_NODES
            ));
        }
        if cfg.cores_per_node == 0 {
            return Err("--cores must be positive".to_string());
        }
        Ok(cfg)
    });
    let cfg = match cfg {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("chaos", &e),
    };
    let t0 = std::time::Instant::now();
    let report = chaos_with(&cfg);
    let wall_s = t0.elapsed().as_secs_f64();
    // `--trace FILE`: additionally replay the flagship cell's faulted leg
    // with tracing armed and stream the capture next to the report.  The
    // replay is a pure observer pass — the report above is untouched.
    let mut trace_ok = true;
    if let Some(path) = args.get("trace") {
        let obs = match trace_obs(args) {
            Ok(obs) => obs,
            Err(e) => return usage_error("chaos", &e),
        };
        trace_ok = match replay_chaos_cell(&cfg, DEFAULT_CELL, &obs, true) {
            Ok(out) => write_trace(path, &out),
            Err(e) => {
                eprintln!("chaos --trace: {e}");
                false
            }
        };
    }
    let code = finish_report(args, "chaos", report, wall_s);
    if trace_ok {
        code
    } else {
        code.max(1)
    }
}

fn cmd_planet(args: &Args) -> i32 {
    use coldfaas::experiments::planet::{planet_config, planet_with};
    let cfg = exp_config(args).and_then(|base| {
        let mut cfg = planet_config(&base);
        cfg.nodes = args.try_get_u64("nodes", cfg.nodes as u64)? as usize;
        cfg.cores_per_node = args.try_get_u32("cores", cfg.cores_per_node)?;
        cfg.checkpoint = checkpoint_flags(args)?;
        tenant_flags(args, &mut cfg.tenant)?;
        if args.has_flag("timeseries") {
            cfg.obs.telemetry_interval_ns = telemetry_interval_ns(cfg.tenant.duration_s);
        }
        if cfg.nodes == 0 || cfg.nodes > coldfaas::platform::MAX_NODES {
            return Err(format!("--nodes must be in 1..={}", coldfaas::platform::MAX_NODES));
        }
        if cfg.cores_per_node == 0 {
            return Err("--cores must be positive".to_string());
        }
        Ok(cfg)
    });
    let cfg = match cfg {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("planet", &e),
    };
    let t0 = std::time::Instant::now();
    let report = planet_with(&cfg);
    finish_report(args, "planet", report, t0.elapsed().as_secs_f64())
}

fn cmd_hyperplanet(args: &Args) -> i32 {
    use coldfaas::experiments::hyperplanet::{hyperplanet_config, hyperplanet_with};
    let cfg = exp_config(args).and_then(|base| {
        let mut cfg = hyperplanet_config(&base);
        cfg.nodes = args.try_get_u64("nodes", cfg.nodes as u64)? as usize;
        cfg.cores_per_node = args.try_get_u32("cores", cfg.cores_per_node)?;
        cfg.shards = args.try_get_u64("shards", cfg.shards as u64)? as usize;
        cfg.checkpoint = checkpoint_flags(args)?;
        tenant_flags(args, &mut cfg.tenant)?;
        if args.has_flag("timeseries") {
            cfg.obs.telemetry_interval_ns = telemetry_interval_ns(cfg.tenant.duration_s);
        }
        if cfg.nodes == 0 || cfg.nodes > coldfaas::platform::MAX_NODES {
            return Err(format!("--nodes must be in 1..={}", coldfaas::platform::MAX_NODES));
        }
        if cfg.cores_per_node == 0 {
            return Err("--cores must be positive".to_string());
        }
        if cfg.shards == 0 {
            return Err("--shards must be positive (1 = the single-engine layout)".to_string());
        }
        Ok(cfg)
    });
    let cfg = match cfg {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("hyperplanet", &e),
    };
    let t0 = std::time::Instant::now();
    let report = hyperplanet_with(&cfg);
    finish_report(args, "hyperplanet", report, t0.elapsed().as_secs_f64())
}

fn cmd_sharing(args: &Args) -> i32 {
    use coldfaas::experiments::sharing::{sharing_config, sharing_with};
    let cfg = exp_config(args).and_then(|base| {
        let mut cfg = sharing_config(&base);
        cfg.nodes = args.try_get_u64("nodes", cfg.nodes as u64)? as usize;
        cfg.cores_per_node = args.try_get_u32("cores", cfg.cores_per_node)?;
        cfg.runtimes = args.try_get_u32("runtimes", cfg.runtimes)?;
        cfg.target_per_key = args.try_get_u32("target", cfg.target_per_key)?;
        cfg.spec_costs_ms = args.try_get_f64_list("spec-costs", &cfg.spec_costs_ms)?;
        tenant_flags(args, &mut cfg.tenant)?;
        if cfg.nodes == 0 || cfg.nodes > coldfaas::platform::MAX_NODES {
            return Err(format!("--nodes must be in 1..={}", coldfaas::platform::MAX_NODES));
        }
        if cfg.cores_per_node == 0 || cfg.runtimes == 0 || cfg.target_per_key == 0 {
            return Err("--cores, --runtimes and --target must be positive".to_string());
        }
        if cfg.spec_costs_ms.is_empty() || cfg.spec_costs_ms.iter().any(|&c| c < 0.0) {
            return Err("--spec-costs needs at least one non-negative cost".to_string());
        }
        Ok(cfg)
    });
    let cfg = match cfg {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("sharing", &e),
    };
    let t0 = std::time::Instant::now();
    let report = sharing_with(&cfg);
    finish_report(args, "sharing", report, t0.elapsed().as_secs_f64())
}

/// `coldfaas trace [cell]` (S25): replay one chaos/planet grid cell with
/// the observability layer armed, write the Chrome trace next to a small
/// replay report.  Pure observer — grid reports and pins are untouched.
fn cmd_trace(args: &Args) -> i32 {
    use coldfaas::experiments::chaos::chaos_config;
    use coldfaas::experiments::planet::planet_config;
    use coldfaas::experiments::replay::{
        replay_chaos_cell, replay_planet_cell, replay_report, DEFAULT_CELL,
    };
    let cell = args.positional.first().map(String::as_str).unwrap_or(DEFAULT_CELL).to_string();
    let experiment = args.get_or("experiment", "chaos");
    let path = args.get_or("trace", "trace.json");
    let t0 = std::time::Instant::now();
    let outcome = exp_config(args).and_then(|base| {
        let mut obs = trace_obs(args)?;
        if args.try_get_u32("cores", 1)? == 0 {
            return Err("--cores must be positive".to_string());
        }
        match experiment.as_str() {
            "chaos" => {
                let mut cfg = chaos_config(&base);
                cfg.nodes = args.try_get_u64("nodes", cfg.nodes as u64)? as usize;
                cfg.cores_per_node = args.try_get_u32("cores", cfg.cores_per_node)?;
                tenant_flags(args, &mut cfg.tenant)?;
                if cfg.nodes < 2 || cfg.nodes > coldfaas::platform::MAX_NODES {
                    return Err(format!(
                        "--nodes must be in 2..={} (a node must survive the fault plan)",
                        coldfaas::platform::MAX_NODES
                    ));
                }
                if args.has_flag("timeseries") {
                    obs.telemetry_interval_ns = telemetry_interval_ns(cfg.tenant.duration_s);
                }
                replay_chaos_cell(&cfg, &cell, &obs, !args.has_flag("baseline"))
            }
            "planet" => {
                if args.has_flag("baseline") {
                    return Err("--baseline only applies to --experiment chaos".to_string());
                }
                let mut cfg = planet_config(&base);
                cfg.nodes = args.try_get_u64("nodes", cfg.nodes as u64)? as usize;
                cfg.cores_per_node = args.try_get_u32("cores", cfg.cores_per_node)?;
                tenant_flags(args, &mut cfg.tenant)?;
                if cfg.nodes == 0 || cfg.nodes > coldfaas::platform::MAX_NODES {
                    return Err(format!("--nodes must be in 1..={}", coldfaas::platform::MAX_NODES));
                }
                if args.has_flag("timeseries") {
                    obs.telemetry_interval_ns = telemetry_interval_ns(cfg.tenant.duration_s);
                }
                replay_planet_cell(&cfg, &cell, &obs)
            }
            other => Err(format!("--experiment must be chaos or planet, got '{other}'")),
        }
    });
    let out = match outcome {
        Ok(out) => out,
        Err(e) => return usage_error("trace", &e),
    };
    if !write_trace(&path, &out) {
        return 1;
    }
    let report = replay_report(&out);
    finish_report(args, "trace", report, t0.elapsed().as_secs_f64())
}

/// `coldfaas livecheck` (E18): the sim-vs-live cross-validation cell.
/// Unlike `experiment <name>` this is *not* fully deterministic — the
/// live leg measures the real serving stack — so it has its own
/// subcommand and is never part of `experiment all`.
fn cmd_livecheck(args: &Args) -> i32 {
    use coldfaas::experiments::livecheck::{livecheck_with, LivecheckConfig};
    let cfg = (|| {
        let mut cfg =
            if args.has_flag("quick") { LivecheckConfig::quick() } else { LivecheckConfig::full() };
        cfg.time_scale = args.try_get_f64("scale", cfg.time_scale)?;
        cfg.seed = args.try_get_u64("seed", cfg.seed)?;
        if cfg.time_scale <= 0.0 || cfg.time_scale.is_nan() {
            return Err("--scale must be positive (the live leg needs a real clock)".to_string());
        }
        Ok(cfg)
    })();
    let cfg = match cfg {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("livecheck", &e),
    };
    let t0 = std::time::Instant::now();
    let report = livecheck_with(&cfg);
    finish_report(args, "livecheck", report, t0.elapsed().as_secs_f64())
}

/// `coldfaas loadgen`: drive a live gateway with the open-loop generator.
/// With no `--target` it self-hosts an S29 live platform so the command
/// is runnable out of the box (no PJRT artifacts needed).
fn cmd_loadgen(args: &Args) -> i32 {
    use coldfaas::live::{loadgen, start, LiveConfig};
    use coldfaas::workload::tenants::{TenantConfig, TenantTrace};
    let parsed = (|| {
        let tenant = TenantConfig {
            functions: args.try_get_u32("functions", 24)?,
            duration_s: args.try_get_f64("duration", 10.0)?,
            total_rps: args.try_get_f64("rps", 50.0)?,
            zipf_exponent: args.try_get_f64("zipf", 1.1)?,
            diurnal_depth: 0.0,
            diurnal_period_s: 60.0,
            bursty_fraction: 0.0,
            seed: args.try_get_u64("seed", 0xE18)?,
        };
        let scale = args.try_get_f64("scale", 1.0)?;
        let senders = args.try_get_u64("senders", 8)? as usize;
        if tenant.functions == 0 || tenant.total_rps <= 0.0 || tenant.duration_s <= 0.0 {
            return Err("--functions, --rps and --duration must be positive".to_string());
        }
        if scale < 0.0 || scale.is_nan() || senders == 0 {
            return Err("--scale must be >= 0 and --senders positive".to_string());
        }
        Ok((tenant, scale, senders))
    })();
    let (tenant, scale, senders) = match parsed {
        Ok(p) => p,
        Err(e) => return usage_error("loadgen", &e),
    };
    let trace = TenantTrace::generate(&tenant);
    let (addr, server) = match args.get("target") {
        Some(t) => match t.parse::<std::net::SocketAddr>() {
            Ok(a) => (a, None),
            Err(e) => return usage_error("loadgen", &format!("--target {t}: {e}")),
        },
        None => {
            let srv = match start(LiveConfig {
                functions: tenant.functions,
                time_scale: scale,
                seed: tenant.seed,
                ..LiveConfig::default()
            }) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("loadgen: self-host live platform: {e}");
                    return 1;
                }
            };
            println!("self-hosted live platform on http://{}", srv.addr());
            (srv.addr(), Some(srv))
        }
    };
    println!(
        "replaying {} arrivals ({} functions, {:.0} rps x {:.0} s) at scale {scale} over {senders} senders",
        trace.arrivals.len(),
        tenant.functions,
        tenant.total_rps,
        tenant.duration_s
    );
    let report = loadgen::run(addr, &trace, scale, senders);
    println!("{}", report.summary());
    if let Some(srv) = server {
        srv.shutdown();
    }
    if report.errors == 0 {
        0
    } else {
        1
    }
}

fn cmd_compare(args: &Args) -> i32 {
    use coldfaas::report::compare::{compare_documents, DEFAULT_TOL};
    let (Some(run_path), Some(base_path)) = (args.positional.first(), args.positional.get(1))
    else {
        eprintln!(
            "usage: coldfaas compare <run.json> <baseline.json> [--tol 0.10] [--deny-bootstrap]"
        );
        return 2;
    };
    let tol = match args.try_get_f64("tol", DEFAULT_TOL) {
        Ok(t) if t >= 0.0 => t,
        Ok(t) => return usage_error("compare", &format!("--tol {t}: must be non-negative")),
        Err(e) => return usage_error("compare", &e),
    };
    let read = |path: &str| {
        std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))
    };
    let docs = read(run_path).and_then(|r| read(base_path).map(|b| (r, b)));
    let (run_doc, base_doc) = match docs {
        Ok(d) => d,
        Err(e) => return usage_error("compare", &e),
    };
    match compare_documents(&run_doc, &base_doc, tol) {
        Ok(cmp) => {
            let mut txt = format!(
                "\n=== compare {run_path} vs {base_path} ===\n{}",
                cmp.render(tol)
            );
            let denied = cmp.bootstrap && args.has_flag("deny-bootstrap");
            if denied {
                txt.push_str(
                    "  FAIL: --deny-bootstrap — the committed baseline is still the \
                     bootstrap placeholder; commit a real one (the CI artifact from this \
                     run, or `make baselines` locally) to arm the gate\n",
                );
            }
            print!("{txt}");
            append_out(args, &txt);
            if cmp.ok() && !denied {
                0
            } else {
                1
            }
        }
        Err(e) => usage_error("compare", &e),
    }
}

fn cmd_lint(args: &Args) -> i32 {
    use coldfaas::analysis;
    let root = args.get_or("root", env!("CARGO_MANIFEST_DIR"));
    let report = match analysis::lint_tree(std::path::Path::new(&root)) {
        Ok(r) => r,
        Err(e) => return usage_error("lint", &e),
    };
    print!("{}", analysis::render_text(&report));
    if let Some(path) = args.get("json") {
        if let Err(e) = std::fs::write(path, analysis::render_json(&report)) {
            return usage_error("lint", &format!("write {path}: {e}"));
        }
    }
    if report.findings.is_empty() {
        0
    } else {
        1
    }
}

fn coord_config(args: &Args) -> Result<Config, String> {
    let mode = match args.get_or("mode", "cold").as_str() {
        "warm" => SchedMode::WarmPool,
        _ => SchedMode::ColdOnly,
    };
    Ok(Config {
        mode,
        time_scale: args.try_get_f64("time-scale", 1.0)?,
        idle_timeout_s: args.try_get_f64("idle-timeout", 30.0)?,
        engine_threads: args.try_get_u64("engines", 1)? as usize,
        gateway_workers: args.try_get_u64("workers", 20)? as usize,
        artifacts_dir: args
            .get("artifacts")
            .map(Into::into)
            .unwrap_or_else(coldfaas::runtime::default_artifacts_dir),
        functions: args
            .get("functions")
            .map(|s| s.split(',').map(str::to_string).collect())
            .unwrap_or_default(),
    })
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = match coord_config(args) {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("serve", &e),
    };
    let bind = args.get_or("bind", "127.0.0.1:8080");
    let coord = match Coordinator::start(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start coordinator: {e}");
            eprintln!("hint: run `make artifacts` first");
            return 1;
        }
    };
    let srv = match coord.serve(&bind) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind {bind}: {e}");
            return 1;
        }
    };
    println!("coldfaas serving on http://{} (mode={:?})", srv.addr(), coord.mode());
    println!("functions:");
    for f in coord.registry() {
        println!("  {:<12} inputs={:<6} flops={}", f.name, f.input_elements, f.flops);
    }
    println!("try: curl -X POST http://{}/invoke/echo", srv.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_invoke(args: &Args) -> i32 {
    let Some(name) = args.positional.first() else {
        eprintln!("usage: coldfaas invoke <fn> [--payload '1,2,...']");
        return 2;
    };
    let mut cfg = match coord_config(args) {
        Ok(cfg) => cfg,
        Err(e) => return usage_error("invoke", &e),
    };
    cfg.functions = vec![name.clone()];
    let coord = match Coordinator::start(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("start: {e}\nhint: run `make artifacts` first");
            return 1;
        }
    };
    let payload = args.get_or("payload", "");
    match coord.invoke(name, payload.as_bytes()) {
        Ok(o) => {
            println!(
                "fn={} cold={} startup_model={:.2} ms exec={:.2} ms total={:.2} ms",
                o.function, o.cold, o.startup_model_ms, o.exec_ms, o.total_ms
            );
            println!(
                "output: sum={:.6} l2={:.6} head={:?}",
                o.output_sum, o.output_l2, o.output_head
            );
            0
        }
        Err(e) => {
            eprintln!("invoke failed: {e}");
            1
        }
    }
}

fn cmd_verify(args: &Args) -> i32 {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(coldfaas::runtime::default_artifacts_dir);
    let rt = match coldfaas::runtime::Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("load artifacts from {}: {e}", dir.display());
            return 1;
        }
    };
    let mut ok = true;
    for name in rt.names() {
        match rt.verify(name) {
            Ok(rep) => {
                println!(
                    "{:<12} sum {:>14.6} (want {:>14.6})  l2 {:>12.6} (want {:>12.6})  {}",
                    name,
                    rep.got_sum,
                    rep.want_sum,
                    rep.got_l2,
                    rep.want_l2,
                    if rep.pass { "PASS" } else { "FAIL" }
                );
                ok &= rep.pass;
            }
            Err(e) => {
                println!("{name:<12} ERROR: {e}");
                ok = false;
            }
        }
    }
    if ok {
        0
    } else {
        1
    }
}

fn cmd_measure_exec(args: &Args) -> i32 {
    let iters = match args.try_get_u64("iters", 50) {
        Ok(n) => n as usize,
        Err(e) => return usage_error("measure-exec", &e),
    };
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(coldfaas::runtime::default_artifacts_dir);
    let rt = match coldfaas::runtime::Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("load artifacts: {e}");
            return 1;
        }
    };
    println!("PJRT CPU execution medians over {iters} iters (update runtime::static_exec_ms):");
    for name in rt.names() {
        match rt.measure_exec_ms(name, iters) {
            Ok(ms) => {
                let compile = rt.get(name).map(|l| l.compile_ms).unwrap_or(f64::NAN);
                println!("  {name:<12} exec {ms:>8.3} ms   (compile {compile:>8.1} ms)");
            }
            Err(e) => println!("  {name:<12} ERROR: {e}"),
        }
    }
    0
}

fn cmd_list(args: &Args) -> i32 {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(coldfaas::runtime::default_artifacts_dir);
    match coldfaas::runtime::Manifest::load(&dir) {
        Ok(m) => {
            for f in &m.functions {
                println!(
                    "{:<12} {:<28} in={:?} out={:?} flops={}",
                    f.name, f.doc, f.inputs[0].shape, f.outputs[0].shape, f.flops
                );
            }
            0
        }
        Err(e) => {
            eprintln!("load manifest: {e}\nhint: run `make artifacts` first");
            1
        }
    }
}
