//! Flat calendar-queue event scheduler (S26): the DES hot path.
//!
//! The engine's pending-event set is overwhelmingly *near-future* — the
//! next event is almost always within a few milliseconds of virtual now.
//! A binary heap pays `O(log n)` pointer-chasing per operation over the
//! whole set; a calendar queue instead hashes each event by time into a
//! ring of fixed-width buckets, so push is an append into a small `Vec`
//! and pop is a linear min-scan of the *current* bucket only.  Far-future
//! events (beyond the ring's horizon) spill into a conventional binary
//! heap and are consulted by a single `peek` per pop, migrating back into
//! the ring in batches when the ring drains.
//!
//! Ordering contract — identical to the heap it replaces: events pop in
//! ascending `(t, seq)` order, where `seq` is a unique insertion serial.
//! The bucket min-scan breaks ties by `seq`, and `seq` uniqueness makes
//! the scan's choice total, so the pop order is deterministic regardless
//! of bucket layout.  In debug builds a shadow `BinaryHeap` re-derives
//! every pop and a `debug_assert` pins the two orders against each other
//! — the same retained-oracle pattern the index fast paths use.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Bucket width: `2^20` ns ≈ 1.05 ms per bucket — the scale of the
/// startup phases and service times that dominate the event population.
const BUCKET_SHIFT: u32 = 20;
/// Ring size (power of two): horizon = `N_BUCKETS << BUCKET_SHIFT`
/// ≈ 4.3 s of virtual time ahead of the cursor.
const N_BUCKETS: usize = 4096;

struct Item<T> {
    t: u64,
    seq: u64,
    val: T,
}

/// Shadow-heap entry (debug oracle + overflow storage): min-heap on
/// `(t, seq)`.
struct HeapItem<T> {
    t: u64,
    seq: u64,
    val: T,
}

impl<T> PartialEq for HeapItem<T> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<T> Eq for HeapItem<T> {}
impl<T> PartialOrd for HeapItem<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for HeapItem<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on (time, seq): earlier first; FIFO for ties.
        other.t.cmp(&self.t).then(other.seq.cmp(&self.seq))
    }
}

/// A timestamp-ordered event queue: calendar ring for the near future,
/// binary-heap overflow for the far future.  `push` assigns each event a
/// unique serial; `pop` returns events in ascending `(t, seq)`.
pub struct CalendarQueue<T> {
    ring: Vec<Vec<Item<T>>>,
    /// Absolute bucket index (`t >> BUCKET_SHIFT`) of the ring cursor.
    /// Ring items always live in absolute buckets `[base, base + N)`.
    base: u64,
    ring_len: usize,
    overflow: BinaryHeap<HeapItem<T>>,
    seq: u64,
    /// Debug-parity oracle: a plain heap over the same events whose pop
    /// order every calendar pop is checked against.
    #[cfg(debug_assertions)]
    shadow: BinaryHeap<HeapItem<()>>,
}

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        CalendarQueue {
            ring: (0..N_BUCKETS).map(|_| Vec::new()).collect(),
            base: 0,
            ring_len: 0,
            overflow: BinaryHeap::new(),
            seq: 0,
            #[cfg(debug_assertions)]
            shadow: BinaryHeap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.ring_len + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert an event at absolute virtual time `t`.  `t` must be at or
    /// after the time of the last popped event (the DES never schedules
    /// into the past), which keeps every insertion at or past the cursor.
    /// A past-cursor push (a contract violation — loud in debug builds)
    /// is clamped into the cursor bucket, where the min-scan still finds
    /// it first: in release builds it pops early, never in a wrong slot
    /// modulo `N_BUCKETS` far in the future.
    pub fn push(&mut self, t: u64, val: T) {
        self.seq += 1;
        let seq = self.seq;
        #[cfg(debug_assertions)]
        self.shadow.push(HeapItem { t, seq, val: () });
        let abs = t >> BUCKET_SHIFT;
        debug_assert!(abs >= self.base, "event scheduled before the cursor");
        let abs = abs.max(self.base);
        if abs < self.base + N_BUCKETS as u64 {
            self.ring[(abs as usize) & (N_BUCKETS - 1)].push(Item { t, seq, val });
            self.ring_len += 1;
        } else {
            self.overflow.push(HeapItem { t, seq, val });
        }
    }

    /// The `(t, seq)` key of the event the next [`Self::pop`] would
    /// return, without removing it.  May advance the cursor past empty
    /// buckets and migrate overflow batches — both invisible to the pop
    /// order (peek-then-pop returns exactly what pop alone would).
    pub fn peek(&mut self) -> Option<(u64, u64)> {
        if self.ring_len == 0 && !self.overflow.is_empty() {
            self.migrate_overflow();
        }
        let ring_min = self.find_ring_min().map(|(b, i)| {
            let it = &self.ring[b][i];
            (it.t, it.seq)
        });
        match (ring_min, self.overflow.peek()) {
            (None, None) => None,
            (Some(r), None) => Some(r),
            (None, Some(top)) => Some((top.t, top.seq)),
            (Some(r), Some(top)) => Some(r.min((top.t, top.seq))),
        }
    }

    /// Remove and return the earliest event by `(t, seq)`.
    pub fn pop(&mut self) -> Option<(u64, u64, T)> {
        if self.ring_len == 0 && !self.overflow.is_empty() {
            self.migrate_overflow();
        }
        let ring_min = self.find_ring_min();
        let take_overflow = match (ring_min, self.overflow.peek()) {
            (None, Some(_)) => true,
            (Some((b, i)), Some(top)) => {
                let it = &self.ring[b][i];
                (top.t, top.seq) < (it.t, it.seq)
            }
            (_, None) => false,
        };
        let out = if take_overflow {
            let top = self.overflow.pop().expect("peeked");
            Some((top.t, top.seq, top.val))
        } else if let Some((b, i)) = ring_min {
            let it = self.ring[b].swap_remove(i);
            self.ring_len -= 1;
            Some((it.t, it.seq, it.val))
        } else {
            None
        };
        #[cfg(debug_assertions)]
        if let Some((t, seq, _)) = &out {
            let oracle = self.shadow.pop().expect("oracle heap in sync");
            debug_assert_eq!(
                (oracle.t, oracle.seq),
                (*t, *seq),
                "calendar pop order diverged from the heap oracle"
            );
        }
        out
    }

    /// Advance the cursor to the first non-empty ring bucket and return
    /// the index of that bucket's `(t, seq)`-minimum item.  All ring
    /// items sit in absolute buckets `[base, base + N)`, which map to
    /// distinct slots, so the first non-empty bucket holds the ring's
    /// global minimum and the cursor advances at most `N` slots.
    fn find_ring_min(&mut self) -> Option<(usize, usize)> {
        if self.ring_len == 0 {
            return None;
        }
        let mut slot = (self.base as usize) & (N_BUCKETS - 1);
        while self.ring[slot].is_empty() {
            self.base += 1;
            slot = (self.base as usize) & (N_BUCKETS - 1);
        }
        let bucket = &self.ring[slot];
        let mut min = 0;
        for (i, it) in bucket.iter().enumerate().skip(1) {
            if (it.t, it.seq) < (bucket[min].t, bucket[min].seq) {
                min = i;
            }
        }
        Some((slot, min))
    }

    /// Canonical snapshot for checkpointing (S27): the seq counter plus
    /// every pending item in ascending `(t, seq)` order.  Deliberately
    /// layout-free — neither the cursor position nor the ring/overflow
    /// placement of an item is observable through the pop order, so the
    /// canonical form keeps the state hash identical between a run that
    /// arrived at this state directly and one that restored into it.
    pub fn snapshot(&self) -> (u64, Vec<(u64, u64, &T)>) {
        let mut items: Vec<(u64, u64, &T)> = self
            .ring
            .iter()
            .flatten()
            .map(|it| (it.t, it.seq, &it.val))
            .chain(self.overflow.iter().map(|h| (h.t, h.seq, &h.val)))
            .collect();
        items.sort_unstable_by_key(|&(t, seq, _)| (t, seq));
        (self.seq, items)
    }

    /// Rebuild a queue from a [`Self::snapshot`]: the seq counter is
    /// restored verbatim (so post-restore pushes continue the same serial
    /// stream) and each item keeps its original `(t, seq)` key, which
    /// fully determines the pop order regardless of bucket layout.
    pub fn restore(seq: u64, items: Vec<(u64, u64, T)>) -> Self {
        let mut q = CalendarQueue::new();
        q.seq = seq;
        q.base = items.iter().map(|&(t, _, _)| t >> BUCKET_SHIFT).min().unwrap_or(0);
        for (t, item_seq, val) in items {
            assert!(item_seq <= seq, "snapshot item serial beyond the seq counter");
            #[cfg(debug_assertions)]
            q.shadow.push(HeapItem { t, seq: item_seq, val: () });
            let abs = (t >> BUCKET_SHIFT).max(q.base);
            if abs < q.base + N_BUCKETS as u64 {
                q.ring[(abs as usize) & (N_BUCKETS - 1)].push(Item { t, seq: item_seq, val });
                q.ring_len += 1;
            } else {
                q.overflow.push(HeapItem { t, seq: item_seq, val });
            }
        }
        q
    }

    /// Always-on structural check (cheap): the cached `ring_len` must
    /// match the actual ring population.  A mismatch means pops/pushes
    /// corrupted the count — release-mode corruption surfaces as a failed
    /// run instead of a silently wrong report.
    pub fn validate(&self) {
        let actual: usize = self.ring.iter().map(Vec::len).sum();
        assert_eq!(
            self.ring_len, actual,
            "calendar ring_len {} out of sync with {} ring items",
            self.ring_len, actual
        );
    }

    /// The ring drained: jump the cursor to the overflow minimum's bucket
    /// and pull every overflow event inside the new horizon into the
    /// ring.  (Heap pops here are batched, not per-event: this runs once
    /// per ring drain, not once per pop.)
    fn migrate_overflow(&mut self) {
        let min_t = self.overflow.peek().expect("overflow non-empty").t;
        self.base = self.base.max(min_t >> BUCKET_SHIFT);
        let horizon = self.base + N_BUCKETS as u64;
        while let Some(top) = self.overflow.peek() {
            if top.t >> BUCKET_SHIFT >= horizon {
                break;
            }
            let it = self.overflow.pop().expect("peeked");
            let abs = it.t >> BUCKET_SHIFT;
            self.ring[(abs as usize) & (N_BUCKETS - 1)].push(Item {
                t: it.t,
                seq: it.seq,
                val: it.val,
            });
            self.ring_len += 1;
        }
    }
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    #[test]
    fn pops_in_time_then_insertion_order() {
        let mut q = CalendarQueue::new();
        q.push(50, 'b');
        q.push(10, 'a');
        q.push(50, 'c');
        q.push(5, 'z');
        assert_eq!(q.len(), 4);
        let order: Vec<char> = std::iter::from_fn(|| q.pop()).map(|(_, _, v)| v).collect();
        assert_eq!(order, vec!['z', 'a', 'b', 'c']);
        assert!(q.is_empty());
    }

    #[test]
    fn far_future_events_round_trip_through_overflow() {
        let mut q = CalendarQueue::new();
        // Horizon is N_BUCKETS << BUCKET_SHIFT ≈ 4.3e9 ns: schedule far
        // beyond it, then near it, and interleave pops with new pushes.
        q.push(300_000_000_000, 1u32); // 300 s: deep overflow
        q.push(1_000, 2);
        q.push(10_000_000_000, 3); // 10 s: overflow
        assert_eq!(q.pop().map(|(t, _, v)| (t, v)), Some((1_000, 2)));
        q.push(2_000, 4);
        assert_eq!(q.pop().map(|(t, _, v)| (t, v)), Some((2_000, 4)));
        assert_eq!(q.pop().map(|(t, _, v)| (t, v)), Some((10_000_000_000, 3)));
        // After migrating to 10 s, an insertion near 10 s lands in-ring
        // and must still order against the remaining overflow event.
        q.push(10_000_000_001, 5);
        assert_eq!(q.pop().map(|(t, _, v)| (t, v)), Some((10_000_000_001, 5)));
        assert_eq!(q.pop().map(|(t, _, v)| (t, v)), Some((300_000_000_000, 1)));
        assert_eq!(q.pop().map(|(t, _, v)| (t, v)), None);
    }

    #[test]
    fn ring_candidate_never_shadows_an_earlier_overflow_event() {
        // Regression shape: the cursor advances past empty buckets
        // (extending the horizon), a later insertion then lands in-ring
        // at a time *after* an event still sitting in overflow; pop must
        // take the overflow event first, not the ring candidate.
        let mut q = CalendarQueue::new();
        let horizon = (N_BUCKETS as u64) << BUCKET_SHIFT;
        q.push(0, 0u32); // ring bucket 0
        q.push(horizon - 1, 1); // ring's last bucket
        q.push(horizon + 2, 2); // one bucket past the horizon: overflow
        assert_eq!(q.pop().map(|(_, _, v)| v), Some(0));
        // Popping the last-bucket event walks the cursor to bucket N-1,
        // so the next horizon now covers the overflow event's bucket...
        assert_eq!(q.pop().map(|(_, _, v)| v), Some(1));
        // ...and this insertion (same bucket, later time) lands in-ring
        // while the earlier event is still in overflow.
        q.push(horizon + 5, 3);
        assert_eq!(q.pop().map(|(_, _, v)| v), Some(2), "overflow event was earlier");
        assert_eq!(q.pop().map(|(_, _, v)| v), Some(3));
    }

    /// Release-profile regression for the past-cursor clamp: without it a
    /// past-cursor push files into `t >> SHIFT (mod N_BUCKETS)` — a slot
    /// the min-scan treats as far-future — and pops *after* later events.
    /// Debug builds reject the push outright (`debug_assert`), so this
    /// only compiles where the assert is compiled out.
    #[cfg(not(debug_assertions))]
    #[test]
    fn past_cursor_push_clamps_into_the_cursor_bucket() {
        let mut q = CalendarQueue::new();
        // Walk the cursor to absolute bucket N + 5 (slot 5).
        let h = ((N_BUCKETS as u64) + 5) << BUCKET_SHIFT;
        q.push(h, 'a');
        assert_eq!(q.pop().map(|(_, _, v)| v), Some('a'));
        // A same-bucket future event, then a past-cursor push (bucket 0,
        // slot 0): the past event must still pop first.
        q.push(h + 1, 'c');
        q.push(0, 'b');
        assert_eq!(q.pop().map(|(t, _, v)| (t, v)), Some((0, 'b')), "past-cursor event pops first");
        assert_eq!(q.pop().map(|(_, _, v)| v), Some('c'));
        assert!(q.is_empty());
        q.validate();
    }

    #[test]
    fn peek_matches_pop_without_consuming() {
        let mut q = CalendarQueue::new();
        assert_eq!(q.peek(), None);
        q.push(50, 'b');
        q.push(10, 'a');
        q.push(10_000_000_000_000, 'z'); // deep overflow
        for _ in 0..3 {
            let key = q.peek().expect("non-empty");
            assert_eq!(q.peek(), Some(key), "peek is idempotent");
            let (t, seq, _) = q.pop().expect("non-empty");
            assert_eq!((t, seq), key);
        }
        assert_eq!(q.peek(), None);
    }

    #[test]
    fn snapshot_restore_preserves_pop_order_and_serial_stream() {
        let mut rng = Rng::new(0x5AFE);
        let mut q = CalendarQueue::new();
        let mut now = 0u64;
        for i in 0..5_000u64 {
            let dt = match rng.next_u64() % 10 {
                0..=6 => rng.next_u64() % 5_000_000,
                7 | 8 => rng.next_u64() % 5_000_000_000,
                _ => rng.next_u64() % 400_000_000_000,
            };
            q.push(now + dt, i);
            if rng.next_u64() % 3 == 0 {
                if let Some((t, _, _)) = q.pop() {
                    now = t;
                }
            }
        }
        // Snapshot mid-run, rebuild, and keep driving both queues with an
        // identical schedule: pop streams must stay identical.
        let (seq, items) = q.snapshot();
        let owned: Vec<(u64, u64, u64)> = items.iter().map(|&(t, s, v)| (t, s, *v)).collect();
        let mut r = CalendarQueue::restore(seq, owned);
        r.validate();
        assert_eq!(r.len(), q.len());
        // The canonical snapshot of the restored queue is byte-identical
        // in content to the original's (the state-hash contract).
        {
            let (sa, ia) = q.snapshot();
            let (sb, ib) = r.snapshot();
            assert_eq!(sa, sb);
            assert_eq!(
                ia.iter().map(|&(t, s, v)| (t, s, *v)).collect::<Vec<_>>(),
                ib.iter().map(|&(t, s, v)| (t, s, *v)).collect::<Vec<_>>()
            );
        }
        for i in 0..8_000u64 {
            let dt = rng.next_u64() % 2_000_000_000;
            q.push(now + dt, i);
            r.push(now + dt, i);
            assert_eq!(q.pop(), r.pop());
            assert_eq!(q.len(), r.len());
        }
        while !q.is_empty() {
            assert_eq!(q.pop(), r.pop());
        }
        assert!(r.is_empty());
    }

    #[test]
    fn validate_passes_on_live_queues() {
        let mut q = CalendarQueue::new();
        q.validate();
        for i in 0..100u64 {
            q.push(i * 3_000_000, i);
        }
        q.validate();
        for _ in 0..50 {
            q.pop();
        }
        q.validate();
    }

    #[test]
    fn matches_reference_heap_on_random_workload() {
        // Drive the calendar and a reference BinaryHeap with the same
        // randomized push/pop schedule; pop streams must be identical.
        let mut rng = Rng::new(0xCA1E_17DA);
        let mut q = CalendarQueue::new();
        let mut reference: BinaryHeap<HeapItem<u64>> = BinaryHeap::new();
        let mut now = 0u64;
        let mut seq = 0u64;
        for round in 0..10_000u64 {
            // Mixed horizon: mostly near-future, a tail far past the ring.
            let dt = match rng.next_u64() % 10 {
                0..=6 => rng.next_u64() % 5_000_000,          // < 5 ms
                7 | 8 => rng.next_u64() % 5_000_000_000,      // < 5 s
                _ => rng.next_u64() % 400_000_000_000,        // < 400 s
            };
            q.push(now + dt, round);
            seq += 1;
            reference.push(HeapItem { t: now + dt, seq, val: round });
            if rng.next_u64() % 3 == 0 {
                let got = q.pop();
                let want = reference.pop().map(|h| (h.t, h.seq, h.val));
                assert_eq!(got, want);
                if let Some((t, _, _)) = got {
                    now = t;
                }
            }
        }
        while let Some(want) = reference.pop() {
            assert_eq!(q.pop(), Some((want.t, want.seq, want.val)));
        }
        assert!(q.is_empty());
    }
}
