//! Discrete-event simulation substrate (S1): deterministic PRNG,
//! latency distributions, and the resource-contention event engine.

pub mod calendar;
pub mod dist;
pub mod engine;
pub mod rng;
pub mod snap;

pub use calendar::CalendarQueue;
pub use dist::{Dist, MS, US};
pub use engine::{
    Domain, Engine, Host, LockClass, PhaseSample, ReqId, Spawn, Step, StepKind, N_LOCKS,
};
pub use rng::Rng;
pub use snap::{fnv1a, fold_chain, Dec, Enc, Fnv, FNV_OFFSET};
