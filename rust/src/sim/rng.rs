//! Deterministic PRNG for the simulator: xoshiro256** (Blackman/Vigna).
//!
//! Every experiment derives all randomness from one seeded stream, so a
//! given seed reproduces a byte-identical report (asserted by tests and
//! relied on by `testkit` shrinking). No external rand crate: the offline
//! registry does not carry one, and the generator is 40 lines.

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller pair.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-component RNGs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style unbiased bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Rejection for u1 == 0 to keep ln finite.
        let mut u1 = self.next_f64();
        while u1 <= f64::MIN_POSITIVE {
            u1 = self.next_f64();
        }
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Full generator state for checkpointing: the xoshiro256** word
    /// state plus the cached Box-Muller spare (which is part of the
    /// output stream — dropping it would shift every later normal draw).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from [`Self::state`]; the restored stream
    /// continues exactly where the snapshotted one left off.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Rng {
        Rng { s, gauss_spare }
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let mut u = self.next_f64();
        while u <= f64::MIN_POSITIVE {
            u = self.next_f64();
        }
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b), "all residues should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn state_round_trip_continues_the_stream() {
        let mut a = Rng::new(0xC0FF_EE);
        for _ in 0..17 {
            a.next_u64();
        }
        a.normal(); // leaves a Box-Muller spare cached
        let (s, spare) = a.state();
        let mut b = Rng::from_state(s, spare);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
