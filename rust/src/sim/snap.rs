//! Snapshot byte codec + FNV-1a-64 hashing (S27).
//!
//! The checkpoint subsystem serializes complete simulator state into a
//! flat byte section at virtual-time barriers; the same bytes feed the
//! rolling state-hash chain.  The codec is deliberately primitive — a
//! length-prefixed little-endian writer/reader with no schema — because
//! the *encoding order* is the schema, documented in DESIGN.md §27 and
//! versioned by [`crate::platform::checkpoint::VERSION`].  Floats are
//! encoded as raw bit patterns so a decode → encode round trip is
//! byte-exact (the whole byte-identity contract rests on this).
//!
//! Decode errors panic with context: a truncated or corrupt snapshot is
//! a hard error, never a silently wrong resume.  Header-level validation
//! (magic, version, config fingerprint) happens before any [`Dec`] is
//! constructed, in `platform::checkpoint`.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into an FNV-1a-64 hash state.
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One step of the rolling state-hash chain: the previous chain value
/// (little-endian) is folded first, then the barrier's state section, so
/// every link depends on the entire history of prior sections.
pub fn fold_chain(prev: u64, section: &[u8]) -> u64 {
    fnv1a(fnv1a(FNV_OFFSET, &prev.to_le_bytes()), section)
}

/// Streaming FNV-1a-64 hasher, for fingerprinting large config-derived
/// data (e.g. a multi-million-arrival tenant trace) without buffering an
/// encoded copy.
#[derive(Clone, Copy, Debug)]
pub struct Fnv(pub u64);

impl Default for Fnv {
    fn default() -> Self {
        Fnv(FNV_OFFSET)
    }
}

impl Fnv {
    pub fn new() -> Fnv {
        Fnv::default()
    }

    pub fn bytes(&mut self, b: &[u8]) -> &mut Self {
        self.0 = fnv1a(self.0, b);
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.u64(v.to_bits())
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64).bytes(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Little-endian byte writer for snapshot sections.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// usize encoded as u64 (snapshots must be layout-independent).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// f64 as its raw bit pattern: decode→encode is byte-exact.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(u32::try_from(s.len()).expect("snapshot string fits u32"));
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Sequence length prefix; the caller writes the elements.
    pub fn len(&mut self, n: usize) {
        self.u64(n as u64);
    }
}

/// Reader over one encoded section.  Every getter panics with context on
/// truncation — a corrupt snapshot must never resume silently wrong.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> &'a [u8] {
        assert!(
            self.pos + n <= self.buf.len(),
            "snapshot truncated: need {n} bytes at offset {} of {}",
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    pub fn u8(&mut self) -> u8 {
        self.take(1)[0]
    }

    pub fn bool(&mut self) -> bool {
        match self.u8() {
            0 => false,
            1 => true,
            other => panic!("snapshot corrupt: bool byte {other}"),
        }
    }

    pub fn u16(&mut self) -> u16 {
        u16::from_le_bytes(self.take(2).try_into().unwrap())
    }

    pub fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    pub fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }

    pub fn u128(&mut self) -> u128 {
        u128::from_le_bytes(self.take(16).try_into().unwrap())
    }

    pub fn usize(&mut self) -> usize {
        usize::try_from(self.u64()).expect("snapshot usize fits the host")
    }

    pub fn f64(&mut self) -> f64 {
        f64::from_bits(self.u64())
    }

    /// Raw byte run of a known length (e.g. an embedded section).
    pub fn bytes(&mut self, n: usize) -> &'a [u8] {
        self.take(n)
    }

    pub fn str(&mut self) -> String {
        let n = self.u32() as usize;
        std::str::from_utf8(self.take(n)).expect("snapshot string is UTF-8").to_string()
    }

    pub fn len(&mut self) -> usize {
        self.usize()
    }

    /// Bytes left unread (0 once a section is fully consumed).
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the section was consumed exactly — trailing bytes mean the
    /// encode and decode orders drifted apart.
    pub fn finish(self) {
        assert_eq!(self.remaining(), 0, "snapshot section has trailing bytes");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a(FNV_OFFSET, b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(FNV_OFFSET, b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(FNV_OFFSET, b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn chain_links_depend_on_history() {
        let a = fold_chain(FNV_OFFSET, b"section-one");
        let b = fold_chain(a, b"section-two");
        // Same second section after a different first section: different
        // chain — each link commits to the whole history.
        let a2 = fold_chain(FNV_OFFSET, b"section-1");
        let b2 = fold_chain(a2, b"section-two");
        assert_ne!(a, a2);
        assert_ne!(b, b2);
        // And the fold is deterministic.
        assert_eq!(b, fold_chain(fold_chain(FNV_OFFSET, b"section-one"), b"section-two"));
    }

    #[test]
    fn streaming_fnv_matches_buffered() {
        let mut h = Fnv::new();
        h.u64(7).str("warm").f64(1.5);
        let mut buf = Vec::new();
        buf.extend_from_slice(&7u64.to_le_bytes());
        buf.extend_from_slice(&4u64.to_le_bytes());
        buf.extend_from_slice(b"warm");
        buf.extend_from_slice(&1.5f64.to_bits().to_le_bytes());
        assert_eq!(h.finish(), fnv1a(FNV_OFFSET, &buf));
    }

    #[test]
    fn codec_round_trips_every_primitive() {
        let mut w = Enc::new();
        w.u8(0xAB);
        w.bool(true);
        w.bool(false);
        w.u16(0xBEEF);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.u128(u128::MAX - 7);
        w.usize(123_456);
        w.f64(-0.0);
        w.f64(f64::NAN);
        w.str("tag:dispatch");
        w.len(9);
        let mut r = Dec::new(&w.buf);
        assert_eq!(r.u8(), 0xAB);
        assert!(r.bool());
        assert!(!r.bool());
        assert_eq!(r.u16(), 0xBEEF);
        assert_eq!(r.u32(), 0xDEAD_BEEF);
        assert_eq!(r.u64(), u64::MAX - 3);
        assert_eq!(r.u128(), u128::MAX - 7);
        assert_eq!(r.usize(), 123_456);
        // Bit-exact floats, including -0.0 and NaN payloads.
        assert_eq!(r.f64().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.f64().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.str(), "tag:dispatch");
        assert_eq!(r.len(), 9);
        r.finish();
    }

    #[test]
    #[should_panic(expected = "snapshot truncated")]
    fn truncated_section_panics_with_context() {
        let mut w = Enc::new();
        w.u32(1);
        let mut r = Dec::new(&w.buf);
        r.u64();
    }

    #[test]
    #[should_panic(expected = "trailing bytes")]
    fn unconsumed_section_fails_finish() {
        let mut w = Enc::new();
        w.u64(1);
        w.u64(2);
        let mut r = Dec::new(&w.buf);
        r.u64();
        r.finish();
    }
}
