//! Discrete-event simulation engine.
//!
//! A *request* is a linear sequence of [`Step`]s (the phase pipeline of a
//! container/VM startup, a network hop, a function execution...).  Timed
//! steps contend for the host's resources — a core pool, serializing
//! kernel-lock classes, and a FIFO disk — which is what makes overload
//! behaviour (the paper's parallelism > cores degradation, Docker's
//! kernel-lock blowup) *emergent* rather than fitted.
//!
//! Experiment-specific logic (warm pools, closed-loop load generation)
//! lives behind the [`Domain`] trait: `Decision` steps let the domain
//! splice steps into a running request, `Effect` steps let it mutate its
//! own state at a point in virtual time, and `done` lets it record the
//! latency and spawn follow-up requests.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Mutex, OnceLock};

use super::calendar::CalendarQueue;
use super::dist::Dist;
use super::rng::Rng;
use super::snap::{Dec, Enc};

pub type ReqId = u32;

/// Serializing kernel/host lock classes (one global queue each).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockClass {
    /// rtnl / network-namespace creation (veth, bridge attach).
    Netns = 0,
    /// Mount table + union-filesystem superblock creation.
    Mount = 1,
    /// IPC/UTS/PID namespace bookkeeping.
    Ipc = 2,
    /// KVM VM creation (kvm_lock + memory-region setup).
    Kvm = 3,
    /// Docker engine internal serialization (container map, libnetwork).
    DockerEngine = 4,
    /// Metadata DB write path (Fn's sqlite global write lock).
    Db = 5,
}
pub const N_LOCKS: usize = 6;

/// What a step does while it holds time.
#[derive(Clone, Copy, Debug)]
pub enum StepKind {
    /// Occupy one CPU core for the sampled duration.
    Cpu,
    /// Hold the given serializing lock for the sampled duration.
    Lock(LockClass),
    /// Pure latency (network RTT, timer); no resource held.
    Delay,
    /// Read this many bytes through the shared FIFO disk.
    Disk(u64),
    /// Occupy one slot of a bounded worker pool (see [`Engine::add_pool`])
    /// for the sampled duration — e.g. the gateway's worker threads.
    /// Ids are `u16`: a 256-node platform takes 7 pools per node, which
    /// overflowed the old `u8` id space at 37 nodes.
    Pool(u16),
    /// Zero-time synchronous callback into the domain.
    Effect(u32),
    /// Zero-time callback; the returned steps replace this one.
    Decision(u32),
}

/// One stage of a request pipeline.
#[derive(Clone, Copy, Debug)]
pub struct Step {
    pub kind: StepKind,
    pub dur: Dist,
    /// Stable phase label, used by tracing / the decomposition experiment.
    pub tag: &'static str,
}

impl Step {
    pub const fn cpu(tag: &'static str, dur: Dist) -> Step {
        Step { kind: StepKind::Cpu, dur, tag }
    }
    pub const fn lock(tag: &'static str, class: LockClass, dur: Dist) -> Step {
        Step { kind: StepKind::Lock(class), dur, tag }
    }
    pub const fn delay(tag: &'static str, dur: Dist) -> Step {
        Step { kind: StepKind::Delay, dur, tag }
    }
    pub const fn disk(tag: &'static str, bytes: u64) -> Step {
        Step { kind: StepKind::Disk(bytes), dur: Dist::Const(0.0), tag }
    }
    pub const fn pool(tag: &'static str, pool: u16, dur: Dist) -> Step {
        Step { kind: StepKind::Pool(pool), dur, tag }
    }
    pub const fn effect(tag: &'static str, id: u32) -> Step {
        Step { kind: StepKind::Effect(id), dur: Dist::Const(0.0), tag }
    }
    pub const fn decision(tag: &'static str, id: u32) -> Step {
        Step { kind: StepKind::Decision(id), dur: Dist::Const(0.0), tag }
    }
}

/// A request to start later (returned by [`Domain::done`]).
pub struct Spawn {
    pub delay_ns: u64,
    pub class: u32,
    pub steps: Vec<Step>,
}

/// Experiment-specific logic driven by the engine.
pub trait Domain {
    /// Called for `Decision` steps; returned steps are spliced in place.
    fn decide(&mut self, _req: ReqId, _class: u32, _tag: u32, _now: u64, _rng: &mut Rng) -> Vec<Step> {
        Vec::new()
    }
    /// Called for `Effect` steps (zero virtual time).
    fn effect(&mut self, _req: ReqId, _class: u32, _tag: u32, _now: u64) {}
    /// Called when a request finishes; records latency, returns follow-ups.
    fn done(&mut self, req: ReqId, class: u32, start_ns: u64, now: u64) -> Vec<Spawn>;
    /// Observation hook: called after each timed step completes when
    /// [`Engine::observe_steps`] is on, with the step's wall span
    /// (arrival at the step through finish, resource wait included).
    /// Pure observation — implementations must not perturb domain state
    /// that measurements read.
    fn observe_step(
        &mut self,
        _req: ReqId,
        _class: u32,
        _tag: &'static str,
        _start_ns: u64,
        _end_ns: u64,
    ) {
    }
}

/// Host resource configuration.
#[derive(Clone, Copy, Debug)]
pub struct Host {
    pub cores: u32,
    pub disk_bw_bytes_per_s: f64,
}

impl Default for Host {
    fn default() -> Self {
        // The paper's testbed: dual-socket Xeon E5-2670 (24 threads used),
        // Samsung PM1633a SAS SSD (~1.2 GB/s sequential read).
        Host { cores: 24, disk_bw_bytes_per_s: 1.2e9 }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ev {
    Start(ReqId),
    Finish(ReqId),
}

/// In-flight request storage, struct-of-arrays (S26).  The hot loop
/// touches one or two fields per request per event; parallel vectors
/// keep those accesses dense instead of striding across whole structs,
/// and freed ids recycle through the free list exactly as the old
/// `Vec<ReqState>` + free-list pair did.
struct ReqArena {
    steps: Vec<Vec<Step>>,
    idx: Vec<usize>,
    start_ns: Vec<u64>,
    step_arrival: Vec<u64>,
    class: Vec<u32>,
    live: Vec<bool>,
    free: Vec<ReqId>,
}

impl ReqArena {
    fn new() -> Self {
        ReqArena {
            steps: Vec::new(),
            idx: Vec::new(),
            start_ns: Vec::new(),
            step_arrival: Vec::new(),
            class: Vec::new(),
            live: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Live + recyclable slot count (bounded by peak concurrency).
    #[cfg(test)]
    fn len(&self) -> usize {
        self.steps.len()
    }

    fn alloc(&mut self, steps: Vec<Step>, at_ns: u64, class: u32) -> ReqId {
        if let Some(id) = self.free.pop() {
            let i = id as usize;
            self.steps[i] = steps;
            self.idx[i] = 0;
            self.start_ns[i] = at_ns;
            self.step_arrival[i] = at_ns;
            self.class[i] = class;
            self.live[i] = true;
            id
        } else {
            self.steps.push(steps);
            self.idx.push(0);
            self.start_ns.push(at_ns);
            self.step_arrival.push(at_ns);
            self.class.push(class);
            self.live.push(true);
            (self.steps.len() - 1) as ReqId
        }
    }
}

#[derive(Default)]
struct LockState {
    busy: bool,
    queue: VecDeque<ReqId>,
}

struct PoolState {
    free: u32,
    queue: VecDeque<ReqId>,
}

/// A recorded (class, phase-tag, wall-duration-ns) sample; wall duration
/// includes resource wait, matching what external measurement would see.
#[derive(Clone, Copy, Debug)]
pub struct PhaseSample {
    pub class: u32,
    pub tag: &'static str,
    pub dur_ns: u64,
}

pub struct Engine<D: Domain> {
    pub domain: D,  // detlint: allow(DL005) encodes itself via Domain::encode_state
    pub rng: Rng,
    pub host: Host, // detlint: allow(DL005) config-derived latency model
    now: u64,
    /// Calendar-queue event scheduler (S26): near-future ring + far-
    /// future overflow heap, popping in the same `(t, seq)` order the
    /// old `BinaryHeap` did (debug builds pin this against a shadow
    /// heap oracle inside the queue).
    queue: CalendarQueue<Ev>,
    reqs: ReqArena,
    cores_free: u32,
    core_queue: VecDeque<ReqId>,
    locks: [LockState; N_LOCKS],
    pools: Vec<PoolState>,
    disk_next_free: u64,
    events_processed: u64,
    /// When true, every timed step records a [`PhaseSample`].
    pub trace_phases: bool, // detlint: allow(DL005) profiling arm-flag, not sim state
    pub phase_trace: Vec<PhaseSample>, // detlint: allow(DL005) observer output, never read back
    /// When true, every timed step calls [`Domain::observe_step`] —
    /// the lifecycle-trace hook (S25).  Off by default.
    pub observe_steps: bool, // detlint: allow(DL005) tracing arm-flag (checkpoint refuses it)
}

impl<D: Domain> Engine<D> {
    pub fn new(domain: D, host: Host, seed: u64) -> Self {
        Engine {
            domain,
            rng: Rng::new(seed),
            host,
            now: 0,
            queue: CalendarQueue::new(),
            reqs: ReqArena::new(),
            cores_free: host.cores,
            core_queue: VecDeque::new(),
            locks: Default::default(),
            pools: Vec::new(),
            disk_next_free: 0,
            events_processed: 0,
            trace_phases: false,
            phase_trace: Vec::new(),
            observe_steps: false,
        }
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Register a bounded worker pool; returns the id for [`Step::pool`].
    pub fn add_pool(&mut self, slots: u32) -> u16 {
        assert!(self.pools.len() < u16::MAX as usize);
        self.pools.push(PoolState { free: slots, queue: VecDeque::new() });
        (self.pools.len() - 1) as u16
    }

    fn push(&mut self, t: u64, ev: Ev) {
        self.queue.push(t, ev);
    }

    /// Seed a request at absolute virtual time `at_ns`.
    pub fn spawn_at(&mut self, at_ns: u64, class: u32, steps: Vec<Step>) -> ReqId {
        let id = self.reqs.alloc(steps, at_ns, class);
        self.push(at_ns, Ev::Start(id));
        id
    }

    /// Run until the event queue drains. Panics if `max_events` is exceeded
    /// (runaway-model backstop).
    pub fn run(&mut self, max_events: u64) {
        self.run_until(u64::MAX, max_events);
    }

    /// Run until the queue drains or the next pending event is at or
    /// after `t_stop` (a checkpoint barrier): only events strictly before
    /// the barrier process, and the barrier itself adds no event and
    /// draws no RNG — the pop stream is exactly the uninterrupted one,
    /// split.  Returns `true` while pending events remain.  `max_events`
    /// is a cumulative budget (compared against total events processed),
    /// so segmented runs share one backstop.
    pub fn run_until(&mut self, t_stop: u64, max_events: u64) -> bool {
        while let Some((t, _)) = self.queue.peek() {
            if t >= t_stop {
                return true;
            }
            let (t, _seq, ev) = self.queue.pop().expect("peeked non-empty");
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            self.events_processed += 1;
            if self.events_processed > max_events {
                panic!("simulation exceeded {max_events} events — runaway model?");
            }
            match ev {
                Ev::Start(r) => {
                    self.reqs.start_ns[r as usize] = self.now;
                    self.advance(r);
                }
                Ev::Finish(r) => self.finish_step(r),
            }
        }
        false
    }

    /// Pending-event count (used by finalize invariants and tests).
    pub fn pending_events(&self) -> usize {
        self.queue.len()
    }

    /// Always-on structural check over the event queue (S27 satellite):
    /// release-mode queue corruption fails the run instead of silently
    /// skewing the report.
    pub fn validate_queue(&self) {
        self.queue.validate();
    }

    /// Serialize the engine core (the S27 "engine" section): virtual
    /// clock, RNG state, the canonical pending-event set, the request
    /// arena verbatim, and every resource-queue state.  `host` and the
    /// pool *registry* are config-derived and rebuilt by normal
    /// construction; only mutable state enters the section.  The arena's
    /// slot layout and free list are deterministic functions of event
    /// history, so uninterrupted and resumed runs agree byte-for-byte.
    pub fn encode_core(&self, w: &mut Enc) {
        w.u64(self.now);
        let (s, spare) = self.rng.state();
        for word in s {
            w.u64(word);
        }
        match spare {
            Some(z) => {
                w.bool(true);
                w.f64(z);
            }
            None => w.bool(false),
        }
        let (seq, items) = self.queue.snapshot();
        w.u64(seq);
        w.len(items.len());
        for (t, s, ev) in items {
            w.u64(t);
            w.u64(s);
            match *ev {
                Ev::Start(id) => {
                    w.u8(0);
                    w.u32(id);
                }
                Ev::Finish(id) => {
                    w.u8(1);
                    w.u32(id);
                }
            }
        }
        w.len(self.reqs.steps.len());
        for i in 0..self.reqs.steps.len() {
            w.len(self.reqs.steps[i].len());
            for step in &self.reqs.steps[i] {
                encode_step(step, w);
            }
            w.usize(self.reqs.idx[i]);
            w.u64(self.reqs.start_ns[i]);
            w.u64(self.reqs.step_arrival[i]);
            w.u32(self.reqs.class[i]);
            w.bool(self.reqs.live[i]);
        }
        w.len(self.reqs.free.len());
        for &id in &self.reqs.free {
            w.u32(id);
        }
        w.u32(self.cores_free);
        w.len(self.core_queue.len());
        for &id in &self.core_queue {
            w.u32(id);
        }
        for lock in &self.locks {
            w.bool(lock.busy);
            w.len(lock.queue.len());
            for &id in &lock.queue {
                w.u32(id);
            }
        }
        w.len(self.pools.len());
        for pool in &self.pools {
            w.u32(pool.free);
            w.len(pool.queue.len());
            for &id in &pool.queue {
                w.u32(id);
            }
        }
        w.u64(self.disk_next_free);
        w.u64(self.events_processed);
    }

    /// Restore the core from [`Self::encode_core`] bytes.  The engine
    /// must be freshly constructed from the same config first (same
    /// host, pools registered in the same order); restore then replaces
    /// every piece of mutable state.
    pub fn restore_core(&mut self, r: &mut Dec) {
        self.now = r.u64();
        let s = [r.u64(), r.u64(), r.u64(), r.u64()];
        let spare = if r.bool() { Some(r.f64()) } else { None };
        self.rng = Rng::from_state(s, spare);
        let seq = r.u64();
        let n = r.len();
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            let t = r.u64();
            let s = r.u64();
            let ev = match r.u8() {
                0 => Ev::Start(r.u32()),
                1 => Ev::Finish(r.u32()),
                other => panic!("snapshot corrupt: event tag {other}"),
            };
            items.push((t, s, ev));
        }
        self.queue = CalendarQueue::restore(seq, items);
        let slots = r.len();
        self.reqs = ReqArena::new();
        for _ in 0..slots {
            let nsteps = r.len();
            let steps: Vec<Step> = (0..nsteps).map(|_| decode_step(r)).collect();
            self.reqs.steps.push(steps);
            self.reqs.idx.push(r.usize());
            self.reqs.start_ns.push(r.u64());
            self.reqs.step_arrival.push(r.u64());
            self.reqs.class.push(r.u32());
            self.reqs.live.push(r.bool());
        }
        let nfree = r.len();
        self.reqs.free = (0..nfree).map(|_| r.u32()).collect();
        self.cores_free = r.u32();
        let ncq = r.len();
        self.core_queue = (0..ncq).map(|_| r.u32()).collect();
        for lock in &mut self.locks {
            lock.busy = r.bool();
            let nq = r.len();
            lock.queue = (0..nq).map(|_| r.u32()).collect();
        }
        let npools = r.len();
        assert_eq!(npools, self.pools.len(), "snapshot pool count mismatch — config drift?");
        for pool in &mut self.pools {
            pool.free = r.u32();
            let nq = r.len();
            pool.queue = (0..nq).map(|_| r.u32()).collect();
        }
        self.disk_next_free = r.u64();
        self.events_processed = r.u64();
    }

    /// Move a request forward through zero-time steps until it blocks on a
    /// timed step, queues on a resource, or completes.
    fn advance(&mut self, r: ReqId) {
        let ri = r as usize;
        loop {
            let idx = self.reqs.idx[ri];
            if idx >= self.reqs.steps[ri].len() {
                self.complete(r);
                return;
            }
            let step = self.reqs.steps[ri][idx];
            match step.kind {
                StepKind::Effect(tag) => {
                    let class = self.reqs.class[ri];
                    self.domain.effect(r, class, tag, self.now);
                    self.reqs.idx[ri] += 1;
                }
                StepKind::Decision(tag) => {
                    let class = self.reqs.class[ri];
                    let new_steps = self.domain.decide(r, class, tag, self.now, &mut self.rng);
                    self.reqs.steps[ri].splice(idx..idx + 1, new_steps);
                }
                StepKind::Delay => {
                    self.reqs.step_arrival[ri] = self.now;
                    let d = step.dur.sample(&mut self.rng);
                    self.push(self.now + d, Ev::Finish(r));
                    return;
                }
                StepKind::Cpu => {
                    self.reqs.step_arrival[ri] = self.now;
                    if self.cores_free > 0 {
                        self.cores_free -= 1;
                        let d = step.dur.sample(&mut self.rng);
                        self.push(self.now + d, Ev::Finish(r));
                    } else {
                        self.core_queue.push_back(r);
                    }
                    return;
                }
                StepKind::Lock(class) => {
                    self.reqs.step_arrival[ri] = self.now;
                    let lock = &mut self.locks[class as usize];
                    if !lock.busy {
                        lock.busy = true;
                        let d = step.dur.sample(&mut self.rng);
                        self.push(self.now + d, Ev::Finish(r));
                    } else {
                        lock.queue.push_back(r);
                    }
                    return;
                }
                StepKind::Disk(bytes) => {
                    self.reqs.step_arrival[ri] = self.now;
                    let service = (bytes as f64 / self.host.disk_bw_bytes_per_s * 1e9) as u64;
                    self.disk_next_free = self.disk_next_free.max(self.now) + service;
                    self.push(self.disk_next_free, Ev::Finish(r));
                    return;
                }
                StepKind::Pool(p) => {
                    self.reqs.step_arrival[ri] = self.now;
                    let pool = &mut self.pools[p as usize];
                    if pool.free > 0 {
                        pool.free -= 1;
                        let d = step.dur.sample(&mut self.rng);
                        self.push(self.now + d, Ev::Finish(r));
                    } else {
                        pool.queue.push_back(r);
                    }
                    return;
                }
            }
        }
    }

    /// A timed step finished: release its resource, hand it to the next
    /// queued request, record the trace, and move on.
    fn finish_step(&mut self, r: ReqId) {
        let ri = r as usize;
        let idx = self.reqs.idx[ri];
        let step = self.reqs.steps[ri][idx];
        match step.kind {
            StepKind::Cpu => {
                if let Some(q) = self.core_queue.pop_front() {
                    // Grant the freed core directly: sample the waiter's
                    // duration now (acquisition time).
                    let qidx = self.reqs.idx[q as usize];
                    let d = self.reqs.steps[q as usize][qidx].dur.sample(&mut self.rng);
                    self.push(self.now + d, Ev::Finish(q));
                } else {
                    self.cores_free += 1;
                }
            }
            StepKind::Lock(class) => {
                let next = self.locks[class as usize].queue.pop_front();
                if let Some(q) = next {
                    let qidx = self.reqs.idx[q as usize];
                    let d = self.reqs.steps[q as usize][qidx].dur.sample(&mut self.rng);
                    self.push(self.now + d, Ev::Finish(q));
                } else {
                    self.locks[class as usize].busy = false;
                }
            }
            StepKind::Pool(p) => {
                let next = self.pools[p as usize].queue.pop_front();
                if let Some(q) = next {
                    let qidx = self.reqs.idx[q as usize];
                    let d = self.reqs.steps[q as usize][qidx].dur.sample(&mut self.rng);
                    self.push(self.now + d, Ev::Finish(q));
                } else {
                    self.pools[p as usize].free += 1;
                }
            }
            StepKind::Delay | StepKind::Disk(_) => {}
            StepKind::Effect(_) | StepKind::Decision(_) => {
                unreachable!("zero-time steps never schedule Finish")
            }
        }
        if self.trace_phases {
            self.phase_trace.push(PhaseSample {
                class: self.reqs.class[ri],
                tag: step.tag,
                dur_ns: self.now - self.reqs.step_arrival[ri],
            });
        }
        if self.observe_steps {
            let (class, arrival) = (self.reqs.class[ri], self.reqs.step_arrival[ri]);
            self.domain.observe_step(r, class, step.tag, arrival, self.now);
        }
        self.reqs.idx[ri] += 1;
        self.advance(r);
    }

    fn complete(&mut self, r: ReqId) {
        let ri = r as usize;
        debug_assert!(self.reqs.live[ri]);
        self.reqs.live[ri] = false;
        let (class, start) = (self.reqs.class[ri], self.reqs.start_ns[ri]);
        let spawns = self.domain.done(r, class, start, self.now);
        self.reqs.free.push(r);
        for s in spawns {
            self.spawn_at(self.now + s.delay_ns, s.class, s.steps);
        }
    }
}

/// Intern a tag string as `&'static str` for snapshot restore.  Live
/// runs carry compile-time literal tags; restored tags are leaked copies
/// registered here, bounded by the distinct-tag population (a few dozen
/// short strings per process, never per restore).
fn intern_tag(s: String) -> &'static str {
    static TAGS: OnceLock<Mutex<BTreeMap<String, &'static str>>> = OnceLock::new();
    let mut map = TAGS.get_or_init(|| Mutex::new(BTreeMap::new())).lock().expect("tag registry");
    if let Some(&t) = map.get(&s) {
        return t;
    }
    let leaked: &'static str = Box::leak(s.clone().into_boxed_str());
    map.insert(s, leaked);
    leaked
}

fn lock_class_from(v: u8) -> LockClass {
    match v {
        0 => LockClass::Netns,
        1 => LockClass::Mount,
        2 => LockClass::Ipc,
        3 => LockClass::Kvm,
        4 => LockClass::DockerEngine,
        5 => LockClass::Db,
        other => panic!("snapshot corrupt: lock class {other}"),
    }
}

fn encode_step(step: &Step, w: &mut Enc) {
    match step.kind {
        StepKind::Cpu => w.u8(0),
        StepKind::Lock(c) => {
            w.u8(1);
            w.u8(c as u8);
        }
        StepKind::Delay => w.u8(2),
        StepKind::Disk(bytes) => {
            w.u8(3);
            w.u64(bytes);
        }
        StepKind::Pool(p) => {
            w.u8(4);
            w.u16(p);
        }
        StepKind::Effect(t) => {
            w.u8(5);
            w.u32(t);
        }
        StepKind::Decision(t) => {
            w.u8(6);
            w.u32(t);
        }
    }
    step.dur.encode(w);
    w.str(step.tag);
}

fn decode_step(r: &mut Dec) -> Step {
    let kind = match r.u8() {
        0 => StepKind::Cpu,
        1 => StepKind::Lock(lock_class_from(r.u8())),
        2 => StepKind::Delay,
        3 => StepKind::Disk(r.u64()),
        4 => StepKind::Pool(r.u16()),
        5 => StepKind::Effect(r.u32()),
        6 => StepKind::Decision(r.u32()),
        other => panic!("snapshot corrupt: step kind {other}"),
    };
    let dur = Dist::decode(r);
    let tag = intern_tag(r.str());
    Step { kind, dur, tag }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dist::MS;

    /// Domain that records latencies and optionally chains new requests.
    struct Collect {
        latencies: Vec<(u32, u64)>,
        remaining: u64,
        template: Vec<Step>,
    }

    impl Domain for Collect {
        fn done(&mut self, _req: ReqId, class: u32, start: u64, now: u64) -> Vec<Spawn> {
            self.latencies.push((class, now - start));
            if self.remaining > 0 {
                self.remaining -= 1;
                vec![Spawn { delay_ns: 0, class, steps: self.template.clone() }]
            } else {
                Vec::new()
            }
        }
    }

    fn engine(remaining: u64, template: Vec<Step>) -> Engine<Collect> {
        Engine::new(
            Collect { latencies: Vec::new(), remaining, template },
            Host { cores: 2, disk_bw_bytes_per_s: 1e9 },
            42,
        )
    }

    #[test]
    fn single_delay_request() {
        let mut e = engine(0, vec![]);
        e.spawn_at(0, 0, vec![Step::delay("d", Dist::const_ms(5.0))]);
        e.run(1000);
        assert_eq!(e.domain.latencies, vec![(0, (5.0 * MS) as u64)]);
    }

    #[test]
    fn steps_are_sequential() {
        let mut e = engine(0, vec![]);
        e.spawn_at(
            0,
            0,
            vec![
                Step::delay("a", Dist::const_ms(2.0)),
                Step::cpu("b", Dist::const_ms(3.0)),
            ],
        );
        e.run(1000);
        assert_eq!(e.domain.latencies[0].1, (5.0 * MS) as u64);
    }

    #[test]
    fn cpu_contention_queues_beyond_cores() {
        // 4 requests, 2 cores, 10 ms each: completions at 10, 10, 20, 20.
        let mut e = engine(0, vec![]);
        for _ in 0..4 {
            e.spawn_at(0, 0, vec![Step::cpu("c", Dist::const_ms(10.0))]);
        }
        e.run(1000);
        let mut l: Vec<u64> = e.domain.latencies.iter().map(|&(_, d)| d).collect();
        l.sort_unstable();
        assert_eq!(l, vec![10_000_000, 10_000_000, 20_000_000, 20_000_000]);
    }

    #[test]
    fn lock_serializes_fully() {
        // 3 requests on one lock, 5 ms each: 5, 10, 15.
        let mut e = engine(0, vec![]);
        for _ in 0..3 {
            e.spawn_at(
                0,
                0,
                vec![Step::lock("l", LockClass::Netns, Dist::const_ms(5.0))],
            );
        }
        e.run(1000);
        let mut l: Vec<u64> = e.domain.latencies.iter().map(|&(_, d)| d).collect();
        l.sort_unstable();
        assert_eq!(l, vec![5_000_000, 10_000_000, 15_000_000]);
    }

    #[test]
    fn disk_is_fifo_bandwidth() {
        // 1e9 B/s; two 0.5 GB reads: finish at 0.5 s and 1.0 s.
        let mut e = engine(0, vec![]);
        e.spawn_at(0, 0, vec![Step::disk("r", 500_000_000)]);
        e.spawn_at(0, 1, vec![Step::disk("r", 500_000_000)]);
        e.run(1000);
        let mut l: Vec<u64> = e.domain.latencies.iter().map(|&(_, d)| d).collect();
        l.sort_unstable();
        assert_eq!(l, vec![500 * MS as u64, 1000 * MS as u64]);
    }

    #[test]
    fn closed_loop_chains_requests() {
        let template = vec![Step::delay("d", Dist::const_ms(1.0))];
        let mut e = engine(9, template.clone());
        e.spawn_at(0, 0, template);
        e.run(10_000);
        assert_eq!(e.domain.latencies.len(), 10);
        assert_eq!(e.now(), (10.0 * MS) as u64);
    }

    struct Splicer;
    impl Domain for Splicer {
        fn decide(&mut self, _r: ReqId, _c: u32, tag: u32, _now: u64, _rng: &mut Rng) -> Vec<Step> {
            if tag == 7 {
                vec![Step::delay("spliced", Dist::const_ms(4.0))]
            } else {
                vec![]
            }
        }
        fn done(&mut self, _r: ReqId, _c: u32, start: u64, now: u64) -> Vec<Spawn> {
            assert_eq!(now - start, 4_000_000);
            Vec::new()
        }
    }

    #[test]
    fn decision_splices_steps() {
        let mut e = Engine::new(Splicer, Host::default(), 1);
        e.spawn_at(0, 0, vec![Step::decision("dec", 7)]);
        e.run(100);
        assert_eq!(e.events_processed(), 2); // Start + Finish of spliced step
    }

    #[test]
    fn empty_decision_is_noop() {
        let mut e = Engine::new(Splicer, Host::default(), 1);
        e.spawn_at(
            0,
            0,
            vec![
                Step::decision("dec", 0),
                Step::delay("d", Dist::const_ms(4.0)),
            ],
        );
        e.run(100);
    }

    #[test]
    fn phase_trace_records_wait_time() {
        let mut e = engine(0, vec![]);
        e.trace_phases = true;
        // Second request waits 5 ms for the lock, so its wall phase is 10 ms.
        for _ in 0..2 {
            e.spawn_at(0, 0, vec![Step::lock("l", LockClass::Mount, Dist::const_ms(5.0))]);
        }
        e.run(100);
        let durs: Vec<u64> = e.phase_trace.iter().map(|p| p.dur_ns).collect();
        assert_eq!(durs, vec![5_000_000, 10_000_000]);
    }

    #[test]
    fn pool_bounds_concurrency() {
        // Pool of 1 slot, 3 requests of 2 ms: completions 2/4/6 ms.
        let mut e = engine(0, vec![]);
        let p = e.add_pool(1);
        for _ in 0..3 {
            e.spawn_at(0, 0, vec![Step::pool("w", p, Dist::const_ms(2.0))]);
        }
        e.run(100);
        let mut l: Vec<u64> = e.domain.latencies.iter().map(|&(_, d)| d).collect();
        l.sort_unstable();
        assert_eq!(l, vec![2_000_000, 4_000_000, 6_000_000]);
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut e = engine(
                100,
                vec![Step::cpu("c", Dist::ms(3.0, 0.3))],
            );
            for _ in 0..4 {
                e.spawn_at(0, 0, vec![Step::cpu("c", Dist::ms(3.0, 0.3))]);
            }
            e.run(100_000);
            e.domain.latencies.clone()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn run_until_splits_the_run_without_changing_it() {
        let run_whole = || {
            let mut e = engine(200, vec![Step::cpu("c", Dist::ms(2.0, 0.3))]);
            for _ in 0..4 {
                e.spawn_at(0, 0, vec![Step::cpu("c", Dist::ms(2.0, 0.3))]);
            }
            e.run(1_000_000);
            (e.domain.latencies.clone(), e.now(), e.events_processed())
        };
        let mut e = engine(200, vec![Step::cpu("c", Dist::ms(2.0, 0.3))]);
        for _ in 0..4 {
            e.spawn_at(0, 0, vec![Step::cpu("c", Dist::ms(2.0, 0.3))]);
        }
        // Walk barriers of 3 ms of virtual time until the queue drains.
        let mut barrier = 3_000_000u64;
        let mut segments = 0;
        while e.run_until(barrier, 1_000_000) {
            barrier += 3_000_000;
            segments += 1;
        }
        assert!(segments > 5, "barriers should split the run many times");
        assert_eq!(run_whole(), (e.domain.latencies.clone(), e.now(), e.events_processed()));
    }

    #[test]
    fn core_snapshot_restore_resumes_identically() {
        let mk = |spawn: bool| {
            let mut e = engine(300, vec![Step::cpu("c", Dist::ms(2.0, 0.3))]);
            let p = e.add_pool(2);
            if spawn {
                for k in 0..6u64 {
                    e.spawn_at(
                        k * 100_000,
                        0,
                        vec![
                            Step::pool("w", p, Dist::ms(1.0, 0.2)),
                            Step::cpu("c", Dist::ms(2.0, 0.3)),
                            Step::lock("l", LockClass::Db, Dist::ms(0.5, 0.1)),
                            Step::delay("d", Dist::ms(0.3, 0.2)),
                            Step::disk("r", 10_000_000),
                        ],
                    );
                }
            }
            e
        };
        // Uninterrupted reference run.
        let mut a = mk(true);
        a.run(1_000_000);
        // Interrupted run: stop mid-flight, snapshot, restore into a
        // freshly constructed engine, continue both.
        let mut b = mk(true);
        assert!(b.run_until(5_000_000, 1_000_000), "barrier should land mid-run");
        let mut w = Enc::new();
        b.encode_core(&mut w);
        let mut c = mk(false);
        c.domain.latencies = b.domain.latencies.clone();
        c.domain.remaining = b.domain.remaining;
        let mut r = Dec::new(&w.buf);
        c.restore_core(&mut r);
        r.finish();
        // Re-encoding right after restore reproduces the same bytes —
        // the state-hash contract (restored state is hash-identical).
        let mut w2 = Enc::new();
        c.encode_core(&mut w2);
        assert_eq!(w.buf, w2.buf, "restore must round-trip byte-exactly");
        b.run(1_000_000);
        c.run(1_000_000);
        assert_eq!(b.domain.latencies, a.domain.latencies);
        assert_eq!(c.domain.latencies, a.domain.latencies);
        assert_eq!(c.now(), a.now());
        assert_eq!(c.events_processed(), a.events_processed());
        c.validate_queue();
        assert_eq!(c.pending_events(), 0);
    }

    #[test]
    fn slot_reuse_bounds_memory() {
        let template = vec![Step::delay("d", Dist::const_ms(1.0))];
        let mut e = engine(1000, template.clone());
        e.spawn_at(0, 0, template);
        e.run(100_000);
        assert!(e.reqs.len() <= 2, "finished slots must be reused");
    }
}
