//! Latency distributions for simulated phases.
//!
//! Startup-phase latencies are modeled as lognormals parameterized by their
//! *median* (what the paper reports) plus a shape sigma; the heavy right
//! tail of a lognormal matches the long-tailed startup samples behind the
//! paper's p99 whiskers.  All samples are returned in nanoseconds.

use super::rng::Rng;
use super::snap::{Dec, Enc};

pub const MS: f64 = 1e6; // ns per millisecond
pub const US: f64 = 1e3; // ns per microsecond

/// A latency distribution; `sample` returns nanoseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dist {
    /// Exactly `ns` nanoseconds.
    Const(f64),
    /// Lognormal with the given median (ns) and log-space sigma.
    LogNormal { median_ns: f64, sigma: f64 },
    /// Exponential with the given mean (ns).
    Exp { mean_ns: f64 },
    /// Uniform in [lo, hi) ns.
    Uniform { lo_ns: f64, hi_ns: f64 },
}

impl Dist {
    /// Lognormal given the median in milliseconds (the unit the paper uses).
    pub const fn ms(median_ms: f64, sigma: f64) -> Dist {
        Dist::LogNormal { median_ns: median_ms * MS, sigma }
    }

    pub const fn const_ms(ms: f64) -> Dist {
        Dist::Const(ms * MS)
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let ns = match *self {
            Dist::Const(ns) => ns,
            Dist::LogNormal { median_ns, sigma } => median_ns * (sigma * rng.normal()).exp(),
            Dist::Exp { mean_ns } => rng.exponential(mean_ns),
            Dist::Uniform { lo_ns, hi_ns } => lo_ns + rng.next_f64() * (hi_ns - lo_ns),
        };
        ns.max(0.0) as u64
    }

    /// The distribution median in nanoseconds (used by calibration checks).
    pub fn median_ns(&self) -> f64 {
        match *self {
            Dist::Const(ns) => ns,
            Dist::LogNormal { median_ns, .. } => median_ns,
            Dist::Exp { mean_ns } => mean_ns * std::f64::consts::LN_2,
            Dist::Uniform { lo_ns, hi_ns } => 0.5 * (lo_ns + hi_ns),
        }
    }

    /// Snapshot codec (S27): variant tag + raw f64 bit patterns, so a
    /// decode → encode round trip is byte-exact.
    pub fn encode(&self, w: &mut Enc) {
        match *self {
            Dist::Const(ns) => {
                w.u8(0);
                w.f64(ns);
            }
            Dist::LogNormal { median_ns, sigma } => {
                w.u8(1);
                w.f64(median_ns);
                w.f64(sigma);
            }
            Dist::Exp { mean_ns } => {
                w.u8(2);
                w.f64(mean_ns);
            }
            Dist::Uniform { lo_ns, hi_ns } => {
                w.u8(3);
                w.f64(lo_ns);
                w.f64(hi_ns);
            }
        }
    }

    /// Inverse of [`Self::encode`]; panics on a corrupt variant tag.
    pub fn decode(r: &mut Dec) -> Dist {
        match r.u8() {
            0 => Dist::Const(r.f64()),
            1 => Dist::LogNormal { median_ns: r.f64(), sigma: r.f64() },
            2 => Dist::Exp { mean_ns: r.f64() },
            3 => Dist::Uniform { lo_ns: r.f64(), hi_ns: r.f64() },
            other => panic!("snapshot corrupt: Dist tag {other}"),
        }
    }

    /// Scale the location parameter by `f` (used for what-if ablations).
    pub fn scaled(&self, f: f64) -> Dist {
        match *self {
            Dist::Const(ns) => Dist::Const(ns * f),
            Dist::LogNormal { median_ns, sigma } => {
                Dist::LogNormal { median_ns: median_ns * f, sigma }
            }
            Dist::Exp { mean_ns } => Dist::Exp { mean_ns: mean_ns * f },
            Dist::Uniform { lo_ns, hi_ns } => Dist::Uniform { lo_ns: lo_ns * f, hi_ns: hi_ns * f },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn median_of(d: Dist, seed: u64, n: usize) -> f64 {
        let mut rng = Rng::new(seed);
        let mut v: Vec<u64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        v.sort_unstable();
        v[n / 2] as f64
    }

    #[test]
    fn const_is_exact() {
        let mut rng = Rng::new(1);
        assert_eq!(Dist::const_ms(5.0).sample(&mut rng), 5_000_000);
    }

    #[test]
    fn lognormal_median_matches_parameter() {
        let d = Dist::ms(150.0, 0.25);
        let med = median_of(d, 2, 50_001);
        assert!((med / (150.0 * MS) - 1.0).abs() < 0.02, "median {med}");
    }

    #[test]
    fn lognormal_right_skewed() {
        let d = Dist::ms(10.0, 0.4);
        let mut rng = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!(mean > 10.0 * MS, "lognormal mean should exceed median");
    }

    #[test]
    fn exp_mean() {
        let d = Dist::Exp { mean_ns: 1000.0 };
        let mut rng = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - 1000.0).abs() < 20.0, "mean {mean}");
    }

    #[test]
    fn uniform_bounds() {
        let d = Dist::Uniform { lo_ns: 100.0, hi_ns: 200.0 };
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((100..200).contains(&x));
        }
    }

    #[test]
    fn scaled_scales_median() {
        let d = Dist::ms(100.0, 0.2).scaled(0.5);
        assert!((d.median_ns() - 50.0 * MS).abs() < 1e-6);
    }

    #[test]
    fn never_negative() {
        let d = Dist::Uniform { lo_ns: -50.0, hi_ns: 1.0 };
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            // saturates at zero rather than wrapping
            assert!(d.sample(&mut rng) < 2);
        }
    }
}
