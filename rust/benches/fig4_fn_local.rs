//! Bench E4 / Fig 4: Fn platform local-lab comparison regeneration.
//!
//!     cargo bench --bench fig4_fn_local

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{fig4, ExpConfig};

fn main() {
    println!("== bench fig4_fn_local: Fn IncludeOS-cold vs Docker-warm ==\n");
    let cfg = ExpConfig::default();
    let t0 = std::time::Instant::now();
    let report = fig4(&cfg);
    print!("{}", report.render());
    println!(
        "\nfull Fig 4 regeneration (10 cells x 10k requests): {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "fig4 regressions: {:#?}", report.failures());
}
