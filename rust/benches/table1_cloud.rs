//! Bench E5 / Table I + E10: cloud-deployment medians regeneration.
//!
//!     cargo bench --bench table1_cloud

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{distance_sweep, table1, ExpConfig};

fn main() {
    println!("== bench table1_cloud: Fn + Lambda from the Stockholm lab ==\n");
    let cfg = ExpConfig::default();
    let t0 = std::time::Instant::now();
    let report = table1(&cfg);
    print!("{}", report.render());
    println!("\nTable I regeneration: {:.2} s wall", t0.elapsed().as_secs_f64());
    assert!(report.all_pass(), "table1 regressions: {:#?}", report.failures());

    let report = distance_sweep(&cfg);
    print!("{}", report.render());
    assert!(report.all_pass(), "distance regressions: {:#?}", report.failures());
}
