//! Bench E13: cluster-scale fleet sweep — lifecycle policy x placement
//! scheduler x driver over a 1000-function Zipf tenant trace on an
//! 8-node cluster, on the unified platform layer.
//!
//!     cargo bench --bench e13_fleet

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{fleet, ExpConfig};

fn main() {
    println!("== bench e13_fleet: the policy lab at cluster scale ==\n");
    let t0 = std::time::Instant::now();
    let report = fleet(&ExpConfig::default());
    print!("{}", report.render());
    println!(
        "\nE13 regeneration (32 cells x ~20k multi-tenant invocations, 8 nodes): {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "e13 regressions: {:#?}", report.failures());
}
