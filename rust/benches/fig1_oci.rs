//! Bench E1 / Fig 1: end-to-end regeneration of the OCI-runtime startup
//! sweep, plus per-cell timing of the DES itself.
//!
//!     cargo bench --bench fig1_oci

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{fig1, startup::sweep, ExpConfig};
use coldfaas::metrics::Recorder;
use coldfaas::testkit::bench;
use coldfaas::virt::Tech;

fn main() {
    println!("== bench fig1_oci: OCI runtimes + Firecracker startup sweep ==\n");

    // Paper-scale regeneration (10 000 requests/cell), timed end to end.
    let cfg = ExpConfig::default();
    let t0 = std::time::Instant::now();
    let report = fig1(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    print!("{}", report.render());
    println!("\nfull Fig 1 regeneration (20 cells x 10k requests): {wall:.2} s wall");
    assert!(report.all_pass(), "fig1 regressions: {:#?}", report.failures());

    // Per-cell micro-bench: one tech at paper load.
    for tech in [Tech::Runc, Tech::Kata] {
        let r = bench(&format!("{} @40x10k cell", tech.name()), 1500, || {
            let mut rec = Recorder::new();
            let cell = ExpConfig { requests: 10_000, parallelisms: vec![40], ..Default::default() };
            sweep(tech, &cell, &mut rec);
            std::hint::black_box(rec.count(&format!("{}@40", tech.name())));
        });
        println!("{}", r.row());
    }
}
