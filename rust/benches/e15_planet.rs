//! Bench E15: planet sweep — 256 nodes x 10 000 functions, a ≥1M-request
//! streamed Zipf trace per cell (includeos cold-only vs the Docker
//! driver under every lifecycle policy), reporting simulator throughput
//! (engine events per wall-clock second) alongside the frontier checks.
//!
//!     cargo bench --bench e15_planet

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{planet, ExpConfig};

fn main() {
    println!("== bench e15_planet: the cold-only claim at planet scale ==\n");
    let t0 = std::time::Instant::now();
    let report = planet(&ExpConfig::default());
    print!("{}", report.render());
    println!(
        "\nE15 regeneration (5 cells x ~1M streamed requests, 256 nodes, 10k fns): \
         {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "e15 regressions: {:#?}", report.failures());
}
