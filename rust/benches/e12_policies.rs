//! Bench E12: keep-alive policy lab — lifecycle policy x driver over a
//! production-shaped multi-tenant Zipf trace (1000 functions), reporting
//! the p50/p99-latency vs GB·s-idle-waste frontier.
//!
//!     cargo bench --bench e12_policies

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{policies, ExpConfig};

fn main() {
    println!("== bench e12_policies: lifecycle policies vs the cold-only thesis ==\n");
    let t0 = std::time::Instant::now();
    let report = policies(&ExpConfig::default());
    print!("{}", report.render());
    println!(
        "\nE12 regeneration (8 cells x ~120k multi-tenant invocations): {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "e12 regressions: {:#?}", report.failures());
}
