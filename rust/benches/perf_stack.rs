//! §Perf micro-benchmarks for the three hot paths (EXPERIMENTS.md §Perf):
//!   L3a  DES engine event throughput (drives every figure regeneration)
//!   L3b  HTTP gateway /noop round trip (the live serving floor)
//!   L3c  dispatch overhead: coordinator invoke minus PJRT exec
//!   L1/L2 PJRT execution per workload (the function-body floor)
//!
//!     cargo bench --bench perf_stack

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use std::sync::Arc;

use coldfaas::coordinator::{Config, Coordinator, SchedMode};
use coldfaas::gateway::http::{http_request, Handler, Response, Server};
use coldfaas::sim::{Dist, Domain, Engine, Host, ReqId, Spawn, Step};
use coldfaas::testkit::bench;
use coldfaas::workload::run_closed_loop;

struct Chain {
    remaining: u64,
}
impl Domain for Chain {
    fn done(&mut self, _r: ReqId, c: u32, _s: u64, _n: u64) -> Vec<Spawn> {
        if self.remaining == 0 {
            return Vec::new();
        }
        self.remaining -= 1;
        vec![Spawn {
            delay_ns: 0,
            class: c,
            steps: vec![Step::cpu("c", Dist::ms(1.0, 0.1))],
        }]
    }
}

fn des_events_per_sec() -> f64 {
    // 200k requests x (Start+Finish) through the cpu-contention path.
    let n: u64 = 200_000;
    let t0 = std::time::Instant::now();
    let mut e = Engine::new(Chain { remaining: n }, Host::default(), 7);
    for _ in 0..32 {
        e.spawn_at(0, 0, vec![Step::cpu("c", Dist::ms(1.0, 0.1))]);
    }
    e.run(n * 8);
    e.events_processed() as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("== perf_stack: hot-path micro-benchmarks ==\n");

    // --- L3a: DES engine ---
    let eps = des_events_per_sec();
    println!("L3a DES engine: {:.2} M events/s  (target >= 1 M/s)", eps / 1e6);
    assert!(eps > 1e6, "DES engine below 1M events/s: {eps}");

    // Closed-loop end-to-end cell as a single number.
    let r = bench("L3a fig-cell 10k req @ p=40 (runc)", 2000, || {
        let res = run_closed_loop(
            coldfaas::virt::Tech::Runc.pipeline(),
            40,
            10_000,
            Host::default(),
            3,
        );
        std::hint::black_box(res.latencies_ns.len());
    });
    println!("{}", r.row());

    // --- L3b: gateway round trip: fresh connection vs keep-alive ---
    let handler: Handler = Arc::new(|_req| Response::ok(""));
    let srv = Server::start("127.0.0.1:0", 8, handler).unwrap();
    let addr = srv.addr();
    let r = bench("L3b gateway /noop (connect per request)", 1500, || {
        let (s, _) = http_request(addr, "GET", "/noop", b"").unwrap();
        assert_eq!(s, 200);
    });
    println!("{}", r.row());
    let cold_conn = r.ns_per_iter_p50;
    let mut client = coldfaas::gateway::http::HttpClient::connect(addr).unwrap();
    let r = bench("L3b gateway /noop (keep-alive)", 1500, || {
        let (s, _) = client.request("GET", "/noop", b"").unwrap();
        assert_eq!(s, 200);
    });
    println!("{}", r.row());
    println!(
        "    keep-alive speedup: {:.2}x  (paper §IV-B: connection reuse is 'a powerful optimization option')",
        cold_conn / r.ns_per_iter_p50
    );
    srv.shutdown();

    // --- L3c + L1/2: live invoke with PJRT ---
    let artifacts = coldfaas::runtime::default_artifacts_dir();
    if artifacts.join("manifest.json").exists() {
        let coord = Coordinator::start(Config {
            mode: SchedMode::ColdOnly,
            time_scale: 0.0, // isolate dispatch overhead from the model sleeps
            functions: vec!["echo".into(), "transformer".into()],
            ..Config::default()
        })
        .expect("coordinator");
        for f in ["echo", "transformer"] {
            let r = bench(&format!("L1/2 invoke {f} (PJRT, no model sleep)"), 2500, || {
                let o = coord.invoke(f, b"").unwrap();
                std::hint::black_box(o.exec_ms);
            });
            println!("{}", r.row());
        }
        // Dispatch overhead = total - exec for the cheapest function.
        let o = coord.invoke("echo", b"").unwrap();
        println!(
            "L3c dispatch overhead (total - exec on echo): {:.3} ms  (target < 0.5 ms)",
            o.total_ms - o.exec_ms
        );
    } else {
        println!("(artifacts missing; run `make artifacts` for the PJRT benches)");
    }
}
