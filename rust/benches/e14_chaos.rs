//! Bench E14: chaos sweep — the E13 fleet under a scripted fault
//! schedule (staggered node crashes with cache flushes and straggler
//! restarts, a fabric brown-out, client retries), every cell paired with
//! a fault-free baseline over the same trace and windows.
//!
//!     cargo bench --bench e14_chaos

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{chaos, ExpConfig};

fn main() {
    println!("== bench e14_chaos: the fleet under failure ==\n");
    let t0 = std::time::Instant::now();
    let report = chaos(&ExpConfig::default());
    print!("{}", report.render());
    println!(
        "\nE14 regeneration (16 cells x 2 legs x ~20k multi-tenant invocations, 8 nodes): \
         {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "e14 regressions: {:#?}", report.failures());
}
