//! Ablation benches for the design choices DESIGN.md calls out:
//!   A1  metadata DB: sqlite (global write lock) vs Postgres under load —
//!       why the paper switched (§IV-B)
//!   A2  FDK unix-socket hop vs raw stdio — why the IncludeOS driver
//!       skips the FDK (§IV-A)
//!   A3  idle-timeout sweep — the warm-pool tradeoff surface (E9)
//!   A4  docker storage driver (overlay2 vs slower unions) — §III-C
//!
//!     cargo bench --bench ablations

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::ExpConfig;
use coldfaas::fnplat::{agent_steps, run_scenario, DbBackend, DriverKind, Scenario};
use coldfaas::fnplat::sim::Load;
use coldfaas::metrics::Recorder;
use coldfaas::sim::{Dist, Host, LockClass, Step};
use coldfaas::workload::{record, run_closed_loop};

fn p50(rec: &Recorder, label: &str) -> f64 {
    rec.quantile(label, 0.5).unwrap()
}

fn main() {
    println!("== ablations ==\n");

    // --- A1: DB backend under concurrency ---
    println!("A1: metadata DB under 30-parallel agent load (10k lookups):");
    let mut rec = Recorder::new();
    for (name, db) in [("sqlite", DbBackend::Sqlite), ("postgres", DbBackend::Postgres)] {
        let r = run_closed_loop(agent_steps(db), 30, 10_000, Host::default(), 11);
        record(&mut rec, name, &r);
        println!(
            "  {name:<9} p50={:>6.2} ms  p99={:>6.2} ms  throughput={:>8.0} req/s",
            p50(&rec, name),
            rec.quantile(name, 0.99).unwrap(),
            r.throughput_rps
        );
    }
    assert!(
        p50(&rec, "sqlite") > 2.0 * p50(&rec, "postgres"),
        "sqlite's write lock must dominate under load (the paper's reason to switch)"
    );

    // --- A2: FDK hop vs stdio ---
    println!("\nA2: FDK unix-socket HTTP hop vs raw stdio attach (per request):");
    let fdk: f64 = DriverKind::DockerWarm
        .warm_invoke_steps()
        .iter()
        .map(|s| s.dur.median_ns() / 1e6)
        .sum();
    let stdio = 0.8; // the IncludeOS driver's stdio-attach phase
    println!("  fdk-path {fdk:.2} ms vs stdio {stdio:.2} ms per invocation");

    // --- A3: idle-timeout tradeoff ---
    println!("\nA3: warm-pool idle-timeout sweep (poisson 20 rps, local lab):");
    let cfg = ExpConfig { requests: 4000, ..Default::default() };
    for timeout in [1.0, 10.0, 30.0, 120.0] {
        let sc = Scenario {
            idle_timeout_s: timeout,
            load: Load::OpenLoop(coldfaas::workload::traces::Trace::poisson(
                20.0, 120.0, cfg.seed,
            )),
            ..Scenario::local(DriverKind::DockerWarm, 1, 1, false)
        };
        let r = run_scenario(&sc, cfg.host);
        let total = r.warm_hits + r.cold_starts;
        println!(
            "  timeout={timeout:>5.0} s  cold={:>5.1}%  idle-waste={:>8.2} GB·s",
            r.cold_starts as f64 / total as f64 * 100.0,
            r.idle_gb_seconds
        );
    }

    // --- A4: storage drivers ---
    println!("\nA4: docker storage driver (overlay2 vs aufs/devicemapper-like):");
    for (name, ms, sigma) in [("overlay2", 40.0, 0.25), ("aufs", 95.0, 0.3), ("devicemapper", 140.0, 0.35)]
    {
        let mut steps = vec![Step::lock("storage", LockClass::Mount, Dist::ms(ms, sigma))];
        steps.extend(coldfaas::virt::profiles::namespace_phases(1.0));
        let r = run_closed_loop(steps, 10, 5000, Host::default(), 13);
        let mut rec = Recorder::new();
        record(&mut rec, name, &r);
        println!(
            "  {name:<14} p50={:>7.2} ms  p99={:>8.2} ms",
            p50(&rec, name),
            rec.quantile(name, 0.99).unwrap()
        );
    }
    println!("\n(§III-C: 'the default option [overlay2] performs the best' — reproduced)");
}
