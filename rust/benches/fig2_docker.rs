//! Bench E2 / Fig 2: full-Docker-stack startup sweep regeneration.
//!
//!     cargo bench --bench fig2_docker

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{fig2, ExpConfig};

fn main() {
    println!("== bench fig2_docker: Docker-stack startup sweep ==\n");
    let cfg = ExpConfig::default();
    let t0 = std::time::Instant::now();
    let report = fig2(&cfg);
    print!("{}", report.render());
    println!(
        "\nfull Fig 2 regeneration (15 cells x 10k requests): {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "fig2 regressions: {:#?}", report.failures());
}
