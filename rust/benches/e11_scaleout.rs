//! Bench E11: cluster burst scale-out — placement policy x image size.
//!
//!     cargo bench --bench e11_scaleout

use coldfaas::experiments::{scaleout, ExpConfig};

fn main() {
    println!("== bench e11_scaleout: co-location vs spread under burst ==\n");
    let t0 = std::time::Instant::now();
    let report = scaleout(&ExpConfig::default());
    print!("{}", report.render());
    println!("\nE11 regeneration: {:.2} s wall", t0.elapsed().as_secs_f64());
    assert!(report.all_pass(), "e11 regressions: {:#?}", report.failures());
}
