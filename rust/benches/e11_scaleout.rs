//! Bench E11: cluster burst scale-out — placement policy x image size.
//!
//!     cargo bench --bench e11_scaleout

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{scaleout, ExpConfig};

fn main() {
    println!("== bench e11_scaleout: co-location vs spread under burst ==\n");
    let t0 = std::time::Instant::now();
    let report = scaleout(&ExpConfig::default());
    print!("{}", report.render());
    println!("\nE11 regeneration: {:.2} s wall", t0.elapsed().as_secs_f64());
    assert!(report.all_pass(), "e11 regressions: {:#?}", report.failures());
}
