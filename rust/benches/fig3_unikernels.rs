//! Bench E3 / Fig 3: processes + unikernels startup sweep regeneration.
//!
//!     cargo bench --bench fig3_unikernels

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{fig3, ExpConfig};

fn main() {
    println!("== bench fig3_unikernels: processes & unikernels sweep ==\n");
    let cfg = ExpConfig::default();
    let t0 = std::time::Instant::now();
    let report = fig3(&cfg);
    print!("{}", report.render());
    println!(
        "\nfull Fig 3 regeneration (30 cells x 10k requests): {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "fig3 regressions: {:#?}", report.failures());
}
