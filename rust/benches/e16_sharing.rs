//! Bench E16: universal-worker sharing sweep — the E13 fleet against
//! runtime-keyed shared warm pools (UniversalPool) across sharing mode x
//! specialization cost, plus the break-even readout vs cold-only
//! IncludeOS.
//!
//!     cargo bench --bench e16_sharing

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{sharing, ExpConfig};

fn main() {
    println!("== bench e16_sharing: universal workers vs cold-only ==\n");
    let t0 = std::time::Instant::now();
    let report = sharing(&ExpConfig::default());
    print!("{}", report.render());
    println!(
        "\nE16 regeneration (8 exclusive + 8 universal cells x ~20k multi-tenant \
         invocations, 8 nodes): {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "e16 regressions: {:#?}", report.failures());
}
