//! Bench E17: hyperplanet sweep — 1024 nodes x 10 000 functions x 8
//! accounting shards, a 2x10^8-request streamed Zipf trace per cell
//! (10^9 aggregate across the grid), cells running concurrently on the
//! sweep runner.  Reports aggregate simulator throughput (engine events
//! per second of grid wall clock) and the parallel speedup over
//! single-engine serial execution alongside the frontier checks.
//!
//! Full mode holds one multi-GB trace plus a clone per in-flight cell:
//! budget ~32 GB of RAM and a long run.
//!
//!     cargo bench --bench e17_hyperplanet

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use coldfaas::experiments::{hyperplanet, ExpConfig};

fn main() {
    println!("== bench e17_hyperplanet: the cold-only claim at sharded scale ==\n");
    let t0 = std::time::Instant::now();
    let report = hyperplanet(&ExpConfig::default());
    print!("{}", report.render());
    println!(
        "\nE17 regeneration (5 cells x 2e8 streamed requests, 1024 nodes, 10k fns, \
         8 shards): {:.2} s wall",
        t0.elapsed().as_secs_f64()
    );
    assert!(report.all_pass(), "e17 regressions: {:#?}", report.failures());
}
