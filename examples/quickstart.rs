//! Quickstart: boot the cold-only platform, deploy the AOT `echo`
//! function, and invoke it through the full request path.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Every invocation pays a fresh (modeled) IncludeOS unikernel boot and a
//! real PJRT execution — the paper's pitch is that this cold path is fast
//! enough to serve every request.

use coldfaas::coordinator::{Config, Coordinator, SchedMode};

fn main() -> anyhow::Result<()> {
    let cfg = Config {
        mode: SchedMode::ColdOnly,
        time_scale: 1.0, // faithful startup-model sleeps
        functions: vec!["echo".into(), "checksum".into()],
        ..Config::default()
    };
    println!("compiling AOT artifacts on the PJRT CPU client...");
    let coord = Coordinator::start(cfg)?;

    println!("\ndeployed functions:");
    for f in coord.registry() {
        println!("  {:<10} {} input elements, {} flops", f.name, f.input_elements, f.flops);
    }

    println!("\n5 cold invocations of echo (each boots a fresh unikernel model):");
    for i in 0..5 {
        let o = coord.invoke("echo", b"").map_err(anyhow::Error::msg)?;
        println!(
            "  #{i}: cold={} startup(model)={:>6.2} ms  exec(PJRT)={:>6.3} ms  total={:>7.2} ms",
            o.cold, o.startup_model_ms, o.exec_ms, o.total_ms
        );
    }

    println!("\nchecksum over a custom payload:");
    let payload: String =
        (0..65536).map(|i| format!("{:.3}", (i % 7) as f32 * 0.5)).collect::<Vec<_>>().join(",");
    let o = coord.invoke("checksum", payload.as_bytes()).map_err(anyhow::Error::msg)?;
    println!(
        "  checksum={:.4}  (startup {:.2} ms + exec {:.3} ms)",
        o.output_sum, o.startup_model_ms, o.exec_ms
    );

    println!("\nno warm pool exists: nothing is left running between requests.");
    Ok(())
}
