//! End-to-end validation driver (DESIGN.md E2E): serve a real ML model —
//! the AOT-compiled transformer block (Pallas attention + fused-MLP
//! kernels) — through the complete live stack:
//!
//!   hey-style clients -> HTTP gateway -> cold-only scheduler
//!     -> IncludeOS startup model -> PJRT engine threads -> response
//!
//! Reports latency percentiles and throughput per parallelism level, and
//! verifies output numerics against the jax oracle values embedded in the
//! manifest.  Results are recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example serve_ml

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use coldfaas::coordinator::{Config, Coordinator, SchedMode};
use coldfaas::gateway::http::http_request;
use coldfaas::metrics::Recorder;

const FUNCTION: &str = "transformer";
const REQUESTS_PER_LEVEL: u64 = 150;
const PARALLELISM: [u32; 3] = [1, 4, 8];

fn main() -> anyhow::Result<()> {
    println!("== coldfaas end-to-end: serving a transformer block over HTTP ==\n");
    let cfg = Config {
        mode: SchedMode::ColdOnly,
        time_scale: 1.0,
        engine_threads: 2,
        functions: vec![FUNCTION.into()],
        ..Config::default()
    };
    println!("compiling {FUNCTION} on 2 PJRT engine threads (one-time deploy cost)...");
    let t0 = std::time::Instant::now();
    let coord = Coordinator::start(cfg)?;
    println!("deploy done in {:.1} s\n", t0.elapsed().as_secs_f64());

    let srv = coord.serve("127.0.0.1:0")?;
    let addr = srv.addr();
    println!("gateway listening on http://{addr}");

    // Oracle value for the default payload, from the artifact manifest.
    let manifest = coldfaas::runtime::Manifest::load(coldfaas::runtime::default_artifacts_dir())?;
    let want_sum = manifest.get(FUNCTION).expect("manifest entry").checks[0].sum;

    println!(
        "\n{:>4}  {:>8}  {:>8}  {:>8}  {:>8}  {:>10}",
        "par", "p50 ms", "p90 ms", "p99 ms", "max ms", "req/s"
    );
    for &par in &PARALLELISM {
        let mut rec = Recorder::new();
        let errors = Arc::new(AtomicU64::new(0));
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        let per_client = REQUESTS_PER_LEVEL / par as u64;
        for _ in 0..par {
            let errors = errors.clone();
            handles.push(std::thread::spawn(move || {
                let mut lat = Vec::new();
                for _ in 0..per_client {
                    let t = std::time::Instant::now();
                    match http_request(addr, "POST", &format!("/invoke/{FUNCTION}"), b"") {
                        Ok((200, body)) => {
                            lat.push(t.elapsed().as_secs_f64() * 1e3);
                            // Verify numerics on the fly.
                            let text = String::from_utf8_lossy(&body);
                            if !text.contains("output_sum") {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        _ => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                lat
            }));
        }
        for h in handles {
            for ms in h.join().unwrap() {
                rec.record_ms("lat", ms);
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let s = rec.stats("lat").expect("latencies");
        let rps = s.n as f64 / elapsed;
        println!(
            "{par:>4}  {:>8.1}  {:>8.1}  {:>8.1}  {:>8.1}  {rps:>10.1}",
            s.p50,
            rec.quantile("lat", 0.90).unwrap(),
            s.p99,
            s.max
        );
        assert_eq!(errors.load(Ordering::Relaxed), 0, "request errors");
    }

    // Numeric verification through the HTTP path.
    let (status, body) = http_request(addr, "POST", &format!("/invoke/{FUNCTION}"), b"")?;
    assert_eq!(status, 200);
    let text = String::from_utf8(body)?;
    let json = coldfaas::runtime::Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let got_sum = json.get("output_sum").and_then(|v| v.as_f64()).unwrap();
    let rel = (got_sum / want_sum - 1.0).abs();
    println!("\nnumeric check vs jax oracle: sum={got_sum:.4} want={want_sum:.4} rel-err={rel:.2e}");
    assert!(rel < 1e-3, "output mismatch");

    let (_, stats) = http_request(addr, "GET", "/stats", b"")?;
    println!("server stats: {}", String::from_utf8_lossy(&stats));
    println!("\nall requests served by COLD starts; no executor outlived its request.");
    srv.shutdown();
    Ok(())
}
