//! Regenerate the startup-latency figures (Figs 1–3) at reduced load —
//! the full paper-scale sweep is `coldfaas experiment fig1|fig2|fig3`.
//!
//!     cargo run --release --example startup_sweep

use coldfaas::experiments::{fig1, fig2, fig3, ExpConfig};

fn main() {
    let cfg = ExpConfig { requests: 3000, parallelisms: vec![1, 10, 20, 40], ..Default::default() };
    println!("closed-loop hey sweep: {} requests/cell, 24-core host model", cfg.requests);
    for (name, report) in
        [("fig1", fig1(&cfg)), ("fig2", fig2(&cfg)), ("fig3", fig3(&cfg))]
    {
        print!("{}", report.render());
        assert!(report.all_pass(), "{name} failed: {:#?}", report.failures());
    }
    println!("\nall paper-vs-measured checks PASS");
}
