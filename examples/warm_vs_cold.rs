//! The paper's core tradeoff, live: the same workload served by
//! (a) the warm-pool baseline (Docker-style, 30 s idle timeout) and
//! (b) the cold-only unikernel platform — comparing latency, cold-start
//! fraction, and the idle-memory waste the warm pool accumulates.
//!
//!     make artifacts && cargo run --release --example warm_vs_cold

use coldfaas::coordinator::{Config, Coordinator, SchedMode};
use coldfaas::metrics::Recorder;

const FUNCTION: &str = "checksum";
const REQUESTS: usize = 60;
/// Request spacing: 200 ms apart keeps the warm pool hot; the interesting
/// contrast is what that warmth costs.
const GAP_MS: u64 = 200;

fn run_mode(mode: SchedMode) -> anyhow::Result<()> {
    let label = match mode {
        SchedMode::ColdOnly => "cold-only (IncludeOS model)",
        SchedMode::WarmPool => "warm-pool (Docker model, 30 s timeout)",
    };
    println!("\n--- {label} ---");
    let coord = Coordinator::start(Config {
        mode,
        time_scale: 1.0,
        functions: vec![FUNCTION.into()],
        ..Config::default()
    })?;

    let mut rec = Recorder::new();
    for _ in 0..REQUESTS {
        let o = coord.invoke(FUNCTION, b"").map_err(anyhow::Error::msg)?;
        rec.record_ms(if o.cold { "cold" } else { "warm" }, o.total_ms);
        std::thread::sleep(std::time::Duration::from_millis(GAP_MS));
    }

    for kind in ["cold", "warm"] {
        if let Some(s) = rec.stats(kind) {
            println!("  {kind:<5} n={:<4} p50={:>7.2} ms  p99={:>7.2} ms", s.n, s.p50, s.p99);
        }
    }
    let (waste_gbs, monitor_events) = coord.waste_snapshot();
    println!("  idle memory waste: {waste_gbs:.4} GB·s   monitor events: {monitor_events}");
    let (p50, p99, _) = coord.stats.total_quantiles_ms();
    println!("  all requests:      p50={p50:.2} ms  p99={p99:.2} ms");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== warm-pool baseline vs cold-only platform, identical workload ==");
    println!("({REQUESTS} requests, one every {GAP_MS} ms, function = {FUNCTION})");
    run_mode(SchedMode::WarmPool)?;
    run_mode(SchedMode::ColdOnly)?;
    println!(
        "\nreading: the warm pool wins a few ms per request but holds executor \
         memory while idle and needs per-function monitoring; the cold-only \
         platform's tail (p99/p50) is flat and its waste is identically zero."
    );
    Ok(())
}
