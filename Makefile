# Convenience targets; the rust crate lives in rust/, the AOT pipeline
# in python/compile (emits rust/artifacts/ for the live stack).

.PHONY: build test lint artifacts experiments policies fleet chaos planet sharing hyperplanet trace baselines resume-smoke livecheck loadgen

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q
	python -m pytest python/tests -q

# Determinism audit (detlint, DESIGN.md S28): wall-clock reads, hash-map
# iteration in the DES core, lenient parses, mutating debug_asserts, and
# snapshot-codec completeness.  Exit 1 on any unsuppressed finding.
lint: build
	./rust/target/release/coldfaas lint

# JAX/Pallas AOT pipeline -> HLO text + manifest under rust/artifacts/.
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

experiments: build
	./rust/target/release/coldfaas experiment all --quick

policies: build
	./rust/target/release/coldfaas policies --quick

fleet: build
	./rust/target/release/coldfaas fleet --quick

chaos: build
	./rust/target/release/coldfaas chaos --quick

planet: build
	./rust/target/release/coldfaas planet --quick

sharing: build
	./rust/target/release/coldfaas sharing --quick

hyperplanet: build
	./rust/target/release/coldfaas hyperplanet --quick

# E18 sim-vs-live cross-validation (DESIGN.md S29): replay one
# deterministic tenant trace through the DES and the live HTTP stack,
# and band each measured heat class's p50 against the DES prediction.
# ~8 s of real-time replay; CI runs the same cell in its `livecheck`
# job.  Drop --quick for the ~20 s full cell.
livecheck: build
	./rust/target/release/coldfaas livecheck --quick

# Open-loop load generator against a self-hosted S29 live platform
# (no PJRT artifacts needed); override the trace with LOADGEN_ARGS,
# e.g. LOADGEN_ARGS='--rps 200 --duration 5 --senders 16'.
loadgen: build
	./rust/target/release/coldfaas loadgen $(LOADGEN_ARGS)

# Replay the flagship chaos cell with the observability layer armed and
# write a Chrome trace_event capture (open trace.json in chrome://tracing
# or https://ui.perfetto.dev).  Override the cell / grid with TRACE_ARGS,
# e.g. TRACE_ARGS='includeos+cold-only+least-loaded --experiment chaos'.
trace: build
	./rust/target/release/coldfaas trace $(TRACE_ARGS) --quick --timeseries --trace trace.json

# S27 kill + resume smoke (mirrors the CI `resume` job): checkpoint the
# E17 quick grid, SIGKILL it right after its first per-cell snapshot
# lands, resume from the snapshot directory, and require the resumed
# report byte-identical (--tol 0) to an uninterrupted reference run.
RESUME_DIR := /tmp/coldfaas-resume-smoke
resume-smoke: build
	rm -rf $(RESUME_DIR) && mkdir -p $(RESUME_DIR)
	./rust/target/release/coldfaas hyperplanet --quick --json $(RESUME_DIR)/ref.json
	./rust/target/release/coldfaas hyperplanet --quick --checkpoint $(RESUME_DIR)/ckpt --json $(RESUME_DIR)/killed.json & \
	pid=$$!; \
	while ! ls $(RESUME_DIR)/ckpt/*.ckpt >/dev/null 2>&1 && kill -0 $$pid 2>/dev/null; do sleep 0.1; done; \
	kill -9 $$pid 2>/dev/null && echo "killed the grid after its first snapshot" || echo "grid finished before the kill"; \
	wait $$pid || true
	./rust/target/release/coldfaas hyperplanet --quick --resume $(RESUME_DIR)/ckpt --json $(RESUME_DIR)/resumed.json
	./rust/target/release/coldfaas compare $(RESUME_DIR)/resumed.json $(RESUME_DIR)/ref.json --tol 0

# Regenerate the CI bench-regression baselines (rust/baselines/) and
# commit the result; the DES is deterministic per seed, so these are
# machine-independent except for the wall-clock fields — of which only
# events/s gates (one-sidedly), so regenerate on the runner class that
# will enforce the throughput floor.  The CI gates run with
# --deny-bootstrap: committed placeholder baselines fail the build loudly
# until this target's output (or the CI bench-quick-report artifact,
# which is the same regenerated set) is committed.
baselines: build
	./rust/target/release/coldfaas experiment all --quick --json rust/baselines/BENCH_quick.json
	./rust/target/release/coldfaas chaos --quick --timeseries --json rust/baselines/BENCH_chaos_quick.json
	./rust/target/release/coldfaas planet --quick --json rust/baselines/BENCH_planet_quick.json
	./rust/target/release/coldfaas hyperplanet --quick --json rust/baselines/BENCH_hyperplanet_quick.json
